//! `Study` — one optimization process (§2): owns storage, sampler and
//! pruner, runs the optimize loop, and exposes ask/tell for custom loops.

use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::core::{
    FrozenTrial, IndexSnapshot, ObservationIndex, OptunaError, StudyDirection, TrialState,
};
use crate::multi::{nondominated_sort, nondominated_sort_constrained, to_losses};
use crate::pruner::{NopPruner, Pruner};
use crate::sampler::{Sampler, StudyContext, TpeSampler};
use crate::storage::{
    get_or_create_study_multi, CachedStorage, InMemoryStorage, ResilienceConfig,
    ResilienceStats, ResilientStorage, Storage, TelemetryStorage, TrialFinish, SEQ_UNTRACKED,
};
use crate::telemetry::{SpanGuard, Telemetry};
use crate::trial::Trial;
use crate::util::stats::nan_max_cmp;

/// Fault-tolerance policy for crash-prone (distributed) execution: how
/// often live workers prove their trials alive, how long a silent trial
/// may stay `Running` before peers reap it, and how many times a reaped
/// configuration is re-enqueued.
#[derive(Clone, Copy, Debug)]
pub struct FailoverConfig {
    /// Interval between `Storage::record_heartbeat` stamps for in-flight
    /// trials.
    pub heartbeat_interval: Duration,
    /// A `Running` trial whose last liveness evidence is older than this
    /// is considered abandoned and flipped to `Failed`. Must comfortably
    /// exceed `heartbeat_interval` (10× is a good default) or scheduler
    /// hiccups reap live workers.
    pub grace: Duration,
    /// Maximum times one configuration is re-enqueued after being reaped.
    pub max_retry: u32,
}

impl FailoverConfig {
    /// Config with `grace = 10 × heartbeat_interval` and 3 retries.
    pub fn new(heartbeat_interval: Duration) -> Self {
        FailoverConfig {
            heartbeat_interval,
            grace: heartbeat_interval * 10,
            max_retry: 3,
        }
    }
}

impl Default for FailoverConfig {
    fn default() -> Self {
        FailoverConfig::new(Duration::from_millis(500))
    }
}

/// Hook deciding whether a reaped trial's configuration is re-enqueued
/// (the `RetryFailedTrialCallback` analog): return `false` to drop it.
/// Retry-budget accounting (`max_retry`) runs before the hook.
///
/// The hook runs **inside the storage backend's critical section** (that
/// atomicity is what keeps capped budgets exact under concurrent reaps —
/// see [`Storage::fail_stale_trials`]), so it must decide from the
/// victim alone and **must not call back into the study or its storage**:
/// the backend lock is held and is not reentrant.
pub type RetryCallback = dyn Fn(&FrozenTrial) -> bool + Send + Sync;

/// A study: the unit of optimization. Cheap to share across threads by
/// reference (`optimize_parallel` uses scoped threads).
pub struct Study {
    pub(crate) storage: Arc<dyn Storage>,
    pub(crate) sampler: Arc<dyn Sampler>,
    pub(crate) pruner: Arc<dyn Pruner>,
    /// Generation-stamped observation index over this study's trials
    /// (`None` when disabled via [`StudyBuilder::observation_index`]).
    pub(crate) obs_index: Option<Mutex<ObservationIndex>>,
    /// Heartbeat/reap/retry policy (`None` = failover disabled).
    pub(crate) failover: Option<FailoverConfig>,
    pub(crate) retry_cb: Option<Arc<RetryCallback>>,
    /// Telemetry domain this study records spans/metrics into (`None` =
    /// uninstrumented; every instrumentation point is one `Option`
    /// check).
    pub(crate) telemetry: Option<Arc<Telemetry>>,
    /// Concrete handle onto the resilience layer when one is in the
    /// stack, kept so [`Study::resilience_stats`] can read its counters
    /// through the `Arc<dyn Storage>` erasure.
    pub(crate) resilient: Option<Arc<ResilientStorage>>,
    pub study_id: u64,
    /// Direction of objective 0 — what every single-objective consumer
    /// (samplers' loss sign, pruners, the observation index) reads. On a
    /// multi-objective study this is `directions[0]`.
    pub direction: StudyDirection,
    /// One direction per objective; length 1 for single-objective studies.
    pub directions: Vec<StudyDirection>,
    pub name: String,
}

/// Fluent construction (`Study::builder().sampler(...).build()?`).
pub struct StudyBuilder {
    name: String,
    directions: Vec<StudyDirection>,
    storage: Option<Arc<dyn Storage>>,
    sampler: Option<Arc<dyn Sampler>>,
    pruner: Option<Arc<dyn Pruner>>,
    sampler_spec: Option<String>,
    pruner_spec: Option<String>,
    seed: u64,
    cache: bool,
    index: bool,
    failover: Option<FailoverConfig>,
    resilience: Option<ResilienceConfig>,
    retry_cb: Option<Arc<RetryCallback>>,
    telemetry: Option<Arc<Telemetry>>,
}

impl StudyBuilder {
    pub fn name(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn direction(mut self, direction: StudyDirection) -> Self {
        self.directions = vec![direction];
        self
    }

    /// Make the study multi-objective: one direction per objective, in
    /// objective order. The objective then reports a vector of the same
    /// arity through [`Study::optimize_multi`] /
    /// [`TrialOutcome::CompleteValues`], and the single-best accessors
    /// (`best_trial`, `best_value`) are replaced by
    /// [`Study::best_trials`] (the Pareto front) and
    /// [`Study::hypervolume`].
    pub fn directions(mut self, directions: &[StudyDirection]) -> Self {
        self.directions = directions.to_vec();
        self
    }

    pub fn storage(mut self, storage: Arc<dyn Storage>) -> Self {
        self.storage = Some(storage);
        self
    }

    pub fn sampler(mut self, sampler: Arc<dyn Sampler>) -> Self {
        self.sampler = Some(sampler);
        self
    }

    pub fn pruner(mut self, pruner: Arc<dyn Pruner>) -> Self {
        self.pruner = Some(pruner);
        self
    }

    /// Resolve the sampler from a registry spec string at [`build`] time —
    /// `"tpe"`, `"tpe:group=true,n_startup=20"`, `"nsga2:population=40,
    /// constraints=true"`, or any name added via
    /// [`crate::registry::register_sampler`]. Mutually exclusive with
    /// [`sampler`]; the seed comes from [`seed`].
    ///
    /// [`build`]: StudyBuilder::build
    /// [`sampler`]: StudyBuilder::sampler
    /// [`seed`]: StudyBuilder::seed
    pub fn sampler_spec(mut self, spec: &str) -> Self {
        self.sampler_spec = Some(spec.to_string());
        self
    }

    /// Resolve the pruner from a registry spec string at build time —
    /// `"asha:reduction=3"`, `"hyperband:max_resource=81"`, `"none"`, etc.
    /// Mutually exclusive with [`StudyBuilder::pruner`].
    pub fn pruner_spec(mut self, spec: &str) -> Self {
        self.pruner_spec = Some(spec.to_string());
        self
    }

    /// Seed handed to spec-resolved samplers/pruners (default 0). Has no
    /// effect on explicitly constructed instances passed via
    /// [`StudyBuilder::sampler`] / [`StudyBuilder::pruner`].
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Enable/disable the write-through snapshot cache around the storage
    /// backend (see [`CachedStorage`]). On by default; turning it off
    /// restores the one-full-clone-per-read behaviour — useful for
    /// benchmarking the raw path (`benches/perf_micro.rs` does).
    pub fn storage_caching(mut self, enabled: bool) -> Self {
        self.cache = enabled;
        self
    }

    /// Enable/disable the generation-stamped observation index (see
    /// [`crate::core::ObservationIndex`]). On by default; turning it off
    /// restores the scan-per-call sampler/pruner hot paths — useful for
    /// benchmarking and for the equivalence suite
    /// (rust/tests/obs_index_equiv.rs), which proves the two paths make
    /// identical decisions.
    pub fn observation_index(mut self, enabled: bool) -> Self {
        self.index = enabled;
        self
    }

    /// Enable fault-tolerant execution: in-flight trials heartbeat on
    /// `cfg.heartbeat_interval`, the optimize loops reap peers' stale
    /// `Running` trials after `cfg.grace`, and reaped configurations are
    /// re-enqueued up to `cfg.max_retry` times. Off by default.
    pub fn failover(mut self, cfg: FailoverConfig) -> Self {
        self.failover = Some(cfg);
        self
    }

    /// Wrap the storage backend in a [`ResilientStorage`]: transient
    /// storage errors ([`crate::storage::ErrorKind::is_transient`]) are
    /// retried with capped exponential backoff under `cfg`'s budget and
    /// deadline, and exhausted heartbeats/reads degrade instead of
    /// erroring. The decorator is applied *under* the snapshot cache
    /// (`Cached⟨Resilient⟨backend⟩⟩`), so degraded reads feed the cache
    /// its own last-good view. Off by default.
    pub fn resilience(mut self, cfg: ResilienceConfig) -> Self {
        self.resilience = Some(cfg);
        self
    }

    /// Attach a telemetry domain: storage ops are timed through a
    /// [`TelemetryStorage`] decorator (inserted between the resilience
    /// layer and the snapshot cache — see [`crate::telemetry`] for the
    /// stack diagram) and the study's ask/tell/reap paths open spans.
    /// Telemetry observes durations and errors only, never results: the
    /// optimization trajectory is bit-identical with it on or off
    /// (rust/tests/determinism.rs). Off by default.
    pub fn telemetry(mut self, telemetry: Arc<Telemetry>) -> Self {
        self.telemetry = Some(telemetry);
        self
    }

    /// Custom retry decision hook; only consulted when failover is
    /// enabled. The hook runs while the storage lock is held and must
    /// not call back into the study or its storage — see
    /// [`RetryCallback`] for the full contract.
    pub fn retry_callback(
        mut self,
        cb: impl Fn(&FrozenTrial) -> bool + Send + Sync + 'static,
    ) -> Self {
        self.retry_cb = Some(Arc::new(cb));
        self
    }

    /// Create (or join, for shared storage) the study.
    pub fn build(self) -> Result<Study, OptunaError> {
        if self.directions.is_empty() {
            return Err(OptunaError::MultiObjective(
                "a study needs at least one objective direction".into(),
            ));
        }
        let storage = self
            .storage
            .unwrap_or_else(|| Arc::new(InMemoryStorage::new()));
        // resilience wraps the backend first, then telemetry, then the
        // cache: a degraded (stale) read feeds the cache its last-good
        // view, and the op histograms time real (post-cache-miss,
        // retries included) storage round-trips
        let (storage, resilient): (Arc<dyn Storage>, Option<Arc<ResilientStorage>>) =
            match self.resilience {
                Some(cfg) => {
                    let r = Arc::new(ResilientStorage::new(storage, cfg));
                    (r.clone(), Some(r))
                }
                None => (storage, None),
            };
        let storage: Arc<dyn Storage> = match &self.telemetry {
            Some(tel) => Arc::new(TelemetryStorage::new(storage, tel.clone())),
            None => storage,
        };
        let storage = if self.cache { CachedStorage::wrap(storage) } else { storage };
        let sampler: Arc<dyn Sampler> = match (self.sampler, &self.sampler_spec) {
            (Some(_), Some(_)) => {
                return Err(OptunaError::InvalidParam(
                    "give either .sampler(instance) or .sampler_spec(string), not both".into(),
                ))
            }
            (None, Some(spec)) => crate::registry::make_sampler(spec, self.seed)
                .map_err(OptunaError::InvalidParam)?,
            (Some(s), None) => s,
            (None, None) => Arc::new(TpeSampler::new(self.seed)),
        };
        let pruner: Arc<dyn Pruner> = match (self.pruner, &self.pruner_spec) {
            (Some(_), Some(_)) => {
                return Err(OptunaError::InvalidParam(
                    "give either .pruner(instance) or .pruner_spec(string), not both".into(),
                ))
            }
            (None, Some(spec)) => crate::registry::make_pruner(spec, self.seed)
                .map_err(OptunaError::InvalidParam)?,
            (Some(p), None) => p,
            (None, None) => Arc::new(NopPruner),
        };
        let study_id = get_or_create_study_multi(storage.as_ref(), &self.name, &self.directions)?;
        let direction = self.directions[0];
        let obs_index = self
            .index
            .then(|| Mutex::new(ObservationIndex::new(direction)));
        Ok(Study {
            storage,
            sampler,
            pruner,
            obs_index,
            failover: self.failover,
            retry_cb: self.retry_cb,
            telemetry: self.telemetry,
            resilient,
            study_id,
            direction,
            directions: self.directions,
            name: self.name,
        })
    }
}

/// Shared set of in-flight trial ids that the heartbeat ticker stamps.
struct HeartbeatRegistry {
    trials: Mutex<HashSet<u64>>,
}

impl HeartbeatRegistry {
    fn new() -> Self {
        HeartbeatRegistry { trials: Mutex::new(HashSet::new()) }
    }

    // The set is only ever mutated via insert/remove, which cannot leave
    // it half-updated — so a panicking objective thread that poisons the
    // mutex leaves perfectly usable state behind. Recover it: treating
    // the poison as fatal would silently stop heartbeats for every
    // *surviving* worker, getting their live trials reaped.
    fn lock(&self) -> std::sync::MutexGuard<'_, HashSet<u64>> {
        self.trials.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn insert(&self, trial_id: u64) {
        self.lock().insert(trial_id);
    }

    fn remove(&self, trial_id: u64) {
        self.lock().remove(&trial_id);
    }

    fn ids(&self) -> Vec<u64> {
        self.lock().iter().copied().collect()
    }
}

/// Evaluate an objective with a panic firewall. A panicking objective is
/// an *objective* failure, not a harness failure: letting it unwind
/// through the optimize loops would poison shared state and strand the
/// heartbeat ticker (the stop flag is only set on the normal exit path),
/// hanging the scope join. `Err(message)` is the extracted panic payload;
/// the caller records it as a `Failed` outcome like any objective error.
fn catch_objective<R>(
    f: impl FnOnce() -> Result<R, OptunaError>,
) -> Result<Result<R, OptunaError>, String> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).map_err(|payload| {
        payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".to_string())
    })
}

/// Result an objective hands back through [`Study::tell`].
pub enum TrialOutcome {
    Complete(f64),
    /// Multi-objective completion: one value per objective, in the
    /// study's [`Study::directions`] order (arity-checked by `tell`).
    CompleteValues(Vec<f64>),
    Pruned,
    Failed(String),
}

impl Study {
    pub fn builder() -> StudyBuilder {
        StudyBuilder {
            name: "study".to_string(),
            directions: vec![StudyDirection::Minimize],
            storage: None,
            sampler: None,
            pruner: None,
            sampler_spec: None,
            pruner_spec: None,
            seed: 0,
            cache: true,
            index: true,
            failover: None,
            resilience: None,
            retry_cb: None,
            telemetry: None,
        }
    }

    /// Number of objectives (the length of [`Study::directions`]).
    pub fn n_objectives(&self) -> usize {
        self.directions.len()
    }

    /// The telemetry domain attached via [`StudyBuilder::telemetry`],
    /// if any.
    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.telemetry.as_ref()
    }

    /// Open a named span on the study's telemetry domain (`None` when
    /// the study is uninstrumented). The guard's drop records the span.
    pub(crate) fn span(&self, name: &'static str) -> Option<SpanGuard<'_>> {
        self.telemetry.as_deref().map(|t| t.span(name))
    }

    /// Live counters of the resilience layer, when one is in the
    /// decorator stack (via [`StudyBuilder::resilience`], or installed
    /// manually by the CLI). `None` without one.
    pub fn resilience_stats(&self) -> Option<ResilienceStats> {
        self.resilient.as_ref().map(|r| r.stats())
    }

    /// Fold the resilience layer's current counters into the telemetry
    /// registry (no-op unless both layers are attached). Called at
    /// export points — end-of-run summaries, the `metrics` subcommand —
    /// so the gauges carry the final numbers.
    pub fn fold_resilience_stats(&self) {
        if let (Some(tel), Some(stats)) = (&self.telemetry, self.resilience_stats()) {
            tel.fold_resilience(&stats);
        }
    }

    /// True when the study optimizes more than one objective.
    pub fn is_multi_objective(&self) -> bool {
        self.directions.len() > 1
    }

    /// Advance the observation index to the storage's current sequence
    /// number and return its snapshot (`None` when the index is
    /// disabled). O(1) on a quiet study — a sequence-number compare —
    /// and O(changed trials) otherwise, via the same delta stream the
    /// snapshot cache uses.
    pub(crate) fn sync_obs_index(&self) -> Result<Option<Arc<IndexSnapshot>>, OptunaError> {
        let Some(index) = &self.obs_index else {
            return Ok(None);
        };
        let _span = self.span("obs_index.sync");
        let mut ix = index.lock().unwrap();
        let seq = self.storage.study_seq(self.study_id)?;
        if seq != SEQ_UNTRACKED && seq == ix.seq() {
            return Ok(Some(ix.snapshot()));
        }
        let delta = self.storage.get_trials_since(self.study_id, ix.seq())?;
        Ok(Some(ix.apply(&delta.trials, delta.seq)))
    }

    /// Begin a trial. `Waiting` trials (reaped configurations re-enqueued
    /// by the failover layer, or anything queued via
    /// [`Storage::enqueue_trial`]) are popped before a fresh trial is
    /// created, so retried configurations resume first.
    ///
    /// For fresh trials, creation in storage is followed by relational
    /// sampling. The history snapshot taken here is shared by every
    /// independent suggest in the trial, and — through the storage cache —
    /// with every concurrent worker: unless the study changed since the
    /// last read, no trial data is cloned at all. The observation index is
    /// synced to the same generation, so every suggest in the trial reads
    /// pre-sorted observation columns instead of scanning the snapshot.
    pub fn ask(&self) -> Result<Trial<'_>, OptunaError> {
        self.ask_registered(None)
    }

    fn ask_registered(
        &self,
        heartbeats: Option<&HeartbeatRegistry>,
    ) -> Result<Trial<'_>, OptunaError> {
        let _span = self.span("study.ask");
        if let Some((trial_id, number)) = self.storage.pop_waiting_trial(self.study_id)? {
            return self.finish_ask(trial_id, number, false, heartbeats);
        }
        let (trial_id, number) = self.storage.create_trial(self.study_id)?;
        self.finish_ask(trial_id, number, true, heartbeats)
    }

    /// Batched [`Study::ask`]: begin `n` trials in one pipeline pass.
    ///
    /// `Waiting` trials are popped first (like `ask`); the remainder is
    /// claimed through [`Storage::create_trials`] — **one** storage
    /// critical section for the whole batch instead of one per trial —
    /// and the history snapshot + observation-index sync run **once**,
    /// shared by every trial in the batch. The sampler's reusable
    /// scratch (e.g. the TPE Parzen buffers) warms once per batch too,
    /// since all suggests of the batch see the same generation.
    ///
    /// All fresh trials of the batch observe the history as of the
    /// batch's start: trial `k` does not see trials `0..k` of its own
    /// batch (they are `Running` and carry no observations yet — exactly
    /// what a sequential ask-without-tell loop sees). Identical suggests
    /// to the sequential path are guarded by `rust/tests/determinism.rs`.
    ///
    /// ```
    /// use optuna_rs::prelude::*;
    ///
    /// let study = Study::builder().name("doc-batch").build().unwrap();
    /// let mut batch = study.ask_batch(4).unwrap();
    /// let outcomes: Vec<f64> = batch
    ///     .iter_mut()
    ///     .map(|t| t.suggest_float("x", 0.0, 1.0).unwrap())
    ///     .collect();
    /// study
    ///     .tell_batch(
    ///         batch
    ///             .into_iter()
    ///             .zip(outcomes)
    ///             .map(|(t, v)| (t, TrialOutcome::Complete(v)))
    ///             .collect(),
    ///     )
    ///     .unwrap();
    /// assert_eq!(study.trials().unwrap().len(), 4);
    /// ```
    pub fn ask_batch(&self, n: usize) -> Result<Vec<Trial<'_>>, OptunaError> {
        self.ask_batch_registered(n, None)
    }

    fn ask_batch_registered(
        &self,
        n: usize,
        heartbeats: Option<&HeartbeatRegistry>,
    ) -> Result<Vec<Trial<'_>>, OptunaError> {
        let _span = self.span("study.ask_batch");
        let mut popped = Vec::with_capacity(n);
        while popped.len() < n {
            match self.storage.pop_waiting_trial(self.study_id)? {
                Some(pair) => popped.push(pair),
                None => break,
            }
        }
        let created = match self.storage.create_trials(self.study_id, n - popped.len()) {
            Ok(created) => created,
            Err(e) => {
                // the pops already flipped trials to Running; don't
                // strand them on a failed claim
                self.release_popped(&popped);
                return Err(e);
            }
        };
        // register every claimed trial — popped retries included — before
        // the (possibly slow) snapshot sync + sampling, for the same
        // reason finish_ask does
        if let Some(reg) = heartbeats {
            for &(trial_id, _) in popped.iter().chain(&created) {
                reg.insert(trial_id);
            }
        }
        let built = (|| {
            // ONE snapshot + ONE index sync shared by the whole batch,
            // popped retries included
            let trials = self.storage.get_trials_snapshot(self.study_id)?;
            let index = self.sync_obs_index()?;
            let mut out = Vec::with_capacity(n);
            for &(trial_id, number) in &popped {
                // a popped Waiting trial replays its stored parameters —
                // read from the snapshot (taken after the pops, so it
                // carries them), not via a per-trial storage round-trip
                let seeded = match trials.get(number as usize) {
                    Some(t) if t.id == trial_id => t.params.clone(),
                    _ => self.storage.get_trial(trial_id)?.params,
                };
                out.push(Trial::resumed(
                    self,
                    trial_id,
                    number,
                    seeded,
                    Arc::clone(&trials),
                    index.clone(),
                ));
            }
            let ctx = StudyContext::with_index(self.direction, &trials, index.as_deref())
                .with_directions(&self.directions);
            let space = self.sampler.infer_relative_search_space(&ctx);
            for &(trial_id, number) in &created {
                let relative = if space.is_empty() {
                    Default::default()
                } else {
                    self.sampler.sample_relative(&ctx, number, &space)
                };
                out.push(Trial::new(
                    self,
                    trial_id,
                    number,
                    relative,
                    space.clone(),
                    Arc::clone(&trials),
                    index.clone(),
                ));
            }
            Ok(out)
        })();
        if built.is_err() {
            // roll back every registration, popped trials included, so
            // the ticker doesn't keep stranded trials alive past their
            // reap grace — and return the popped configurations to the
            // queue instead of stranding them Running
            if let Some(reg) = heartbeats {
                for &(trial_id, _) in popped.iter().chain(&created) {
                    reg.remove(trial_id);
                }
            }
            self.release_popped(&popped);
        }
        built
    }

    /// Best-effort release of popped-but-unreturnable `Waiting` trials
    /// (an `ask_batch` error path): re-enqueue each configuration so the
    /// retry is not lost, then fail the popped trial so it neither stays
    /// `Running` forever nor holds a capped-budget slot. Every step is
    /// best effort — this runs while storage is already erroring.
    fn release_popped(&self, popped: &[(u64, u64)]) {
        for &(trial_id, _) in popped {
            if let Ok(t) = self.storage.get_trial(trial_id) {
                self.storage
                    .enqueue_trial(self.study_id, &t.params, &t.user_attrs)
                    .ok();
            }
            self.storage
                .set_trial_user_attr(trial_id, "fail_reason", "ask_batch aborted after pop")
                .ok();
            self.storage
                .finish_trial(trial_id, TrialState::Failed, None)
                .ok();
        }
    }

    /// Budget-capped [`Study::ask`]: pops a waiting trial if one exists,
    /// else creates a fresh trial only while the study holds fewer than
    /// `cap` non-`Failed` trials (see [`Storage::create_trial_capped`]).
    /// `Ok(None)` means the budget is claimed — by finished work or by
    /// peers' in-flight trials.
    pub fn ask_capped(&self, cap: u64) -> Result<Option<Trial<'_>>, OptunaError> {
        self.ask_capped_registered(cap, None)
    }

    fn ask_capped_registered(
        &self,
        cap: u64,
        heartbeats: Option<&HeartbeatRegistry>,
    ) -> Result<Option<Trial<'_>>, OptunaError> {
        let _span = self.span("study.ask");
        if let Some((trial_id, number)) = self.storage.pop_waiting_trial(self.study_id)? {
            return self.finish_ask(trial_id, number, false, heartbeats).map(Some);
        }
        match self.storage.create_trial_capped(self.study_id, cap)? {
            Some((trial_id, number)) => {
                self.finish_ask(trial_id, number, true, heartbeats).map(Some)
            }
            None => Ok(None),
        }
    }

    /// Second half of an ask: register the claimed trial for heartbeats
    /// *before* the (possibly slow) snapshot sync + relational sampling —
    /// otherwise a long sampling phase has only `datetime_start` as
    /// liveness evidence and a peer could reap the live trial mid-ask.
    fn finish_ask(
        &self,
        trial_id: u64,
        number: u64,
        fresh: bool,
        heartbeats: Option<&HeartbeatRegistry>,
    ) -> Result<Trial<'_>, OptunaError> {
        if let Some(reg) = heartbeats {
            reg.insert(trial_id);
        }
        let built = if fresh {
            self.build_fresh_trial(trial_id, number)
        } else {
            self.resume_popped(trial_id, number)
        };
        if built.is_err() {
            if let Some(reg) = heartbeats {
                reg.remove(trial_id);
            }
        }
        built
    }

    fn build_fresh_trial(&self, trial_id: u64, number: u64) -> Result<Trial<'_>, OptunaError> {
        let trials = self.storage.get_trials_snapshot(self.study_id)?;
        let index = self.sync_obs_index()?;
        let ctx = StudyContext::with_index(self.direction, &trials, index.as_deref())
            .with_directions(&self.directions);
        let space = self.sampler.infer_relative_search_space(&ctx);
        let relative = if space.is_empty() {
            Default::default()
        } else {
            self.sampler.sample_relative(&ctx, number, &space)
        };
        Ok(Trial::new(self, trial_id, number, relative, space, trials, index))
    }

    /// Build the live-trial view of a just-popped `Waiting` trial: its
    /// stored parameters become the suggest cache, so the objective's
    /// `suggest_*` calls replay the enqueued configuration instead of
    /// sampling anew.
    fn resume_popped(&self, trial_id: u64, number: u64) -> Result<Trial<'_>, OptunaError> {
        let seeded = self.storage.get_trial(trial_id)?.params;
        let trials = self.storage.get_trials_snapshot(self.study_id)?;
        let index = self.sync_obs_index()?;
        Ok(Trial::resumed(self, trial_id, number, seeded, trials, index))
    }

    /// Reap stale `Running` trials (dead peers' work) and re-enqueue
    /// their configurations, honoring `max_retry` and the retry callback.
    /// The requeue decision runs inside the storage's critical section
    /// (see [`Storage::fail_stale_trials`]), so the victim's freed budget
    /// slot and the `Waiting` retry that re-consumes it swap atomically —
    /// a concurrent capped creation can't race into the gap and overshoot
    /// an exact budget. Returns the reaped victims; no-op without a
    /// failover config.
    pub fn reap_stale_trials(&self) -> Result<Vec<FrozenTrial>, OptunaError> {
        let Some(cfg) = self.failover else {
            return Ok(Vec::new());
        };
        let _span = self.span("study.reap");
        let retry_cb = self.retry_cb.clone();
        let requeue = move |v: &FrozenTrial| -> Option<BTreeMap<String, String>> {
            let retries = v.retry_count();
            if retries >= cfg.max_retry {
                return None;
            }
            if let Some(cb) = &retry_cb {
                if !cb(v) {
                    return None;
                }
            }
            let mut attrs = BTreeMap::new();
            attrs.insert("retry_count".to_string(), (retries + 1).to_string());
            attrs.insert("retried_from".to_string(), v.number.to_string());
            Some(attrs)
        };
        self.storage.fail_stale_trials(self.study_id, cfg.grace, &requeue)
    }

    /// Heartbeat ticker body: every `interval`, stamp all registered
    /// in-flight trials. Runs until `stop` is set; polls in small slices
    /// so shutdown doesn't wait out a long interval.
    fn heartbeat_loop(&self, interval: Duration, registry: &HeartbeatRegistry, stop: &AtomicBool) {
        let slice = interval.min(Duration::from_millis(10)).max(Duration::from_millis(1));
        let mut elapsed = Duration::ZERO;
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            elapsed += slice;
            if elapsed < interval {
                continue;
            }
            elapsed = Duration::ZERO;
            for id in registry.ids() {
                // best effort: a failed heartbeat only risks an early reap
                let _ = self.storage.record_heartbeat(id);
            }
        }
    }

    /// Finish a trial with an outcome. Scalar and vector completions are
    /// arity-checked against [`Study::directions`] — a scalar tell on a
    /// multi-objective study (or a wrong-length vector) is a typed
    /// [`OptunaError::MultiObjective`], not silent data corruption.
    pub fn tell(&self, trial: Trial<'_>, outcome: TrialOutcome) -> Result<(), OptunaError> {
        let _span = self.span("study.tell");
        match outcome {
            TrialOutcome::Complete(v) => {
                if self.is_multi_objective() {
                    return Err(OptunaError::MultiObjective(format!(
                        "scalar tell on a {}-objective study — use TrialOutcome::CompleteValues",
                        self.n_objectives()
                    )));
                }
                self.storage.finish_trial(trial.trial_id, TrialState::Complete, Some(v))
            }
            TrialOutcome::CompleteValues(vs) => {
                if vs.len() != self.n_objectives() {
                    return Err(OptunaError::MultiObjective(format!(
                        "objective returned {} values, study has {} objectives",
                        vs.len(),
                        self.n_objectives()
                    )));
                }
                self.storage
                    .finish_trial_values(trial.trial_id, TrialState::Complete, &vs)
            }
            TrialOutcome::Pruned => {
                let v = trial.last_report.map(|(_, v)| v);
                self.storage.finish_trial(trial.trial_id, TrialState::Pruned, v)
            }
            TrialOutcome::Failed(msg) => {
                self.storage
                    .set_trial_user_attr(trial.trial_id, "fail_reason", &msg)
                    .ok();
                self.storage.finish_trial(trial.trial_id, TrialState::Failed, None)
            }
        }
    }

    /// Batched [`Study::tell`]: finish a batch of trials in **one**
    /// storage round-trip ([`Storage::finish_trials`] — one critical
    /// section, one journal record).
    ///
    /// Outcomes are arity-checked like `tell`; a check failure rejects
    /// the call before anything is written (the trials stay running).
    /// Without failover, a storage [`OptunaError::Conflict`] rejects the
    /// whole batch atomically and propagates. With failover configured,
    /// a conflict means a peer reaped one of the batch's trials — the
    /// batch degrades to per-trial finishes with the conflicting entries
    /// skipped, mirroring the optimize loops' conflict policy.
    pub fn tell_batch(
        &self,
        batch: Vec<(Trial<'_>, TrialOutcome)>,
    ) -> Result<(), OptunaError> {
        let _span = self.span("study.tell_batch");
        let mut finishes = Vec::with_capacity(batch.len());
        let mut fail_reasons: Vec<(u64, String)> = Vec::new();
        for (trial, outcome) in batch {
            let (finish, reason) = self.outcome_to_finish(&trial, outcome)?;
            if let Some(msg) = reason {
                fail_reasons.push((finish.trial_id, msg));
            }
            finishes.push(finish);
        }
        // `fail_reason` attributes land only after every outcome passed
        // its checks, so an arity-check rejection really writes nothing
        self.record_fail_reasons(&fail_reasons);
        self.finish_batch(finishes)
    }

    /// Convert one trial outcome to its storage finish record, applying
    /// the same arity checks as [`Study::tell`]. Performs **no** storage
    /// writes: a failure's `fail_reason` comes back as the second tuple
    /// element for the caller to record once batch-wide checks passed.
    fn outcome_to_finish(
        &self,
        trial: &Trial<'_>,
        outcome: TrialOutcome,
    ) -> Result<(TrialFinish, Option<String>), OptunaError> {
        Ok(match outcome {
            TrialOutcome::Complete(v) => {
                if self.is_multi_objective() {
                    return Err(OptunaError::MultiObjective(format!(
                        "scalar tell on a {}-objective study — use TrialOutcome::CompleteValues",
                        self.n_objectives()
                    )));
                }
                (
                    TrialFinish {
                        trial_id: trial.trial_id,
                        state: TrialState::Complete,
                        values: vec![v],
                    },
                    None,
                )
            }
            TrialOutcome::CompleteValues(vs) => {
                if vs.len() != self.n_objectives() {
                    return Err(OptunaError::MultiObjective(format!(
                        "objective returned {} values, study has {} objectives",
                        vs.len(),
                        self.n_objectives()
                    )));
                }
                (
                    TrialFinish {
                        trial_id: trial.trial_id,
                        state: TrialState::Complete,
                        values: vs,
                    },
                    None,
                )
            }
            TrialOutcome::Pruned => (
                TrialFinish {
                    trial_id: trial.trial_id,
                    state: TrialState::Pruned,
                    values: trial.last_report.map(|(_, v)| vec![v]).unwrap_or_default(),
                },
                None,
            ),
            TrialOutcome::Failed(msg) => (
                TrialFinish {
                    trial_id: trial.trial_id,
                    state: TrialState::Failed,
                    values: Vec::new(),
                },
                Some(msg),
            ),
        })
    }

    /// Record `fail_reason` attributes for a batch's failed outcomes
    /// (best effort, like the single-trial tell path).
    fn record_fail_reasons(&self, reasons: &[(u64, String)]) {
        for (trial_id, msg) in reasons {
            self.storage
                .set_trial_user_attr(*trial_id, "fail_reason", msg)
                .ok();
        }
    }

    /// Land a batch of finishes, applying the failover conflict policy
    /// (see [`Study::tell_batch`]).
    fn finish_batch(&self, finishes: Vec<TrialFinish>) -> Result<(), OptunaError> {
        match self.storage.finish_trials(&finishes) {
            Err(e)
                if self.failover.is_some()
                    && (matches!(e, OptunaError::Conflict(_)) || e.is_transient()) =>
            {
                // a peer reaped part of the batch (or the batched write
                // transiently failed): land the rest individually,
                // skipping superseded or still-unreachable entries
                for f in finishes {
                    match self.storage.finish_trial_values(f.trial_id, f.state, &f.values) {
                        Err(e)
                            if matches!(e, OptunaError::Conflict(_)) || e.is_transient() => {}
                        other => other?,
                    }
                }
                Ok(())
            }
            other => other,
        }
    }

    /// Run one trial through `objective` (the optimize-loop body).
    pub fn run_one<F>(&self, objective: &F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError>,
    {
        let trial = self.ask()?;
        self.run_trial(trial, objective, None)
    }

    /// Evaluate `objective` on an already-asked trial and tell the
    /// outcome. Registers the trial with the heartbeat registry for the
    /// duration when one is provided. With failover configured, a storage
    /// [`OptunaError::Conflict`] on tell (the trial was reaped by a peer
    /// that thought us dead — it is already `Failed` and re-enqueued) is
    /// swallowed: the work is superseded, not broken. Without failover,
    /// conflicts propagate.
    fn run_trial<F>(
        &self,
        mut trial: Trial<'_>,
        objective: &F,
        heartbeats: Option<&HeartbeatRegistry>,
    ) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError>,
    {
        let trial_id = trial.id();
        if let Some(reg) = heartbeats {
            reg.insert(trial_id);
        }
        let outcome = match catch_objective(|| objective(&mut trial)) {
            Ok(Ok(v)) if v.is_finite() => TrialOutcome::Complete(v),
            Ok(Ok(v)) => TrialOutcome::Failed(format!("non-finite objective value {v}")),
            Ok(Err(OptunaError::TrialPruned)) => TrialOutcome::Pruned,
            Ok(Err(e)) => TrialOutcome::Failed(e.to_string()),
            Err(panic_msg) => {
                TrialOutcome::Failed(format!("objective panicked: {panic_msg}"))
            }
        };
        let result = self.tell(trial, outcome);
        if let Some(reg) = heartbeats {
            reg.remove(trial_id);
        }
        match result {
            // only under an explicit failover policy: a study that never
            // opted into reaping should surface conflicts, not eat results.
            // Transient storage errors get the same treatment: the trial
            // stops heartbeating, so the reaper will fail + re-enqueue it
            // — superseded work, not a broken study.
            Err(e)
                if self.failover.is_some()
                    && (matches!(e, OptunaError::Conflict(_)) || e.is_transient()) =>
            {
                Ok(())
            }
            other => other,
        }
    }

    /// Evaluate `objective` for `n_trials` trials (the 'optimize API').
    /// Pruned and failed trials are recorded, not fatal.
    ///
    /// ```
    /// use optuna_rs::prelude::*;
    ///
    /// let study = Study::builder().name("doc-optimize").build().unwrap();
    /// study.optimize(20, |trial| {
    ///     let x = trial.suggest_float("x", -10.0, 10.0)?;
    ///     Ok((x - 2.0).powi(2))
    /// }).unwrap();
    /// assert_eq!(study.trials().unwrap().len(), 20);
    /// assert!(study.best_value().unwrap().is_some());
    /// ```
    pub fn optimize<F>(&self, n_trials: usize, objective: F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError>,
    {
        for _ in 0..n_trials {
            self.run_one(&objective)?;
        }
        Ok(())
    }

    /// Parallel optimization with `n_workers` threads sharing this study's
    /// storage — the paper's Fig 7/11b architecture in-process. The total
    /// across workers is `n_trials`. Workers coordinate only through
    /// storage; the snapshot cache hands all of them the same `Arc`'d
    /// trial history per generation — the history is copied at most once
    /// per storage generation (when a delta lands while workers still
    /// hold the previous snapshot), not once per reader as on the
    /// uncached path.
    ///
    /// ```
    /// use optuna_rs::prelude::*;
    ///
    /// let study = Study::builder().name("doc-parallel").build().unwrap();
    /// study.optimize_parallel(16, 4, |trial| {
    ///     let x = trial.suggest_float("x", 0.0, 1.0)?;
    ///     Ok(x * x)
    /// }).unwrap();
    /// assert_eq!(study.trials().unwrap().len(), 16);
    /// ```
    pub fn optimize_parallel<F>(
        &self,
        n_trials: usize,
        n_workers: usize,
        objective: F,
    ) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError> + Sync,
        Self: Sync,
    {
        self.optimize_parallel_batched(n_trials, n_workers, 1, objective)
    }

    /// [`Study::optimize_parallel`] with a per-worker batch size: each
    /// worker claims up to `batch_size` budget slots at once, begins them
    /// through [`Study::ask_batch`] (one storage critical section + one
    /// snapshot sync per batch), evaluates them sequentially, and lands
    /// the outcomes through one batched tell. `batch_size == 1` is
    /// exactly the unbatched loop. Larger batches trade suggest
    /// freshness (trials within a batch don't observe each other) for
    /// storage throughput — the right trade when the objective is cheap
    /// and storage is the bottleneck (see `benches/fig_throughput.rs`).
    pub fn optimize_parallel_batched<F>(
        &self,
        n_trials: usize,
        n_workers: usize,
        batch_size: usize,
        objective: F,
    ) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError> + Sync,
        Self: Sync,
    {
        assert!(n_workers >= 1);
        assert!(batch_size >= 1);
        let budget = AtomicUsize::new(n_trials);
        let first_error = std::sync::Mutex::new(None::<OptunaError>);
        let registry = HeartbeatRegistry::new();
        let stop_ticker = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let ticker = self.failover.map(|cfg| {
                let interval = cfg.heartbeat_interval;
                let (reg, stop) = (&registry, &stop_ticker);
                scope.spawn(move || self.heartbeat_loop(interval, reg, stop))
            });
            let workers: Vec<_> = (0..n_workers)
                .map(|_| {
                    scope.spawn(|| loop {
                        // claim up to batch_size trial slots
                        let prev = budget.fetch_update(
                            Ordering::SeqCst,
                            Ordering::SeqCst,
                            |b| {
                                if b == 0 {
                                    None
                                } else {
                                    Some(b - b.min(batch_size))
                                }
                            },
                        );
                        let Ok(prev) = prev else {
                            break;
                        };
                        let take = prev.min(batch_size);
                        let result = self
                            .reap_stale_trials()
                            .and_then(|_| self.ask_batch_registered(take, Some(&registry)))
                            .and_then(|trials| {
                                self.run_batch(trials, &objective, Some(&registry))
                            });
                        if let Err(e) = result {
                            if self.failover.is_some() && e.is_transient() {
                                // storage transiently unreachable past the
                                // resilience layer's retry budget: return
                                // the claimed slots and retry the batch.
                                // The ask paths roll back claims on error,
                                // and under failover a post-claim failure
                                // is reaped + re-enqueued, so slots are
                                // not double-spent.
                                budget.fetch_add(take, Ordering::SeqCst);
                                std::thread::sleep(Duration::from_millis(1));
                                continue;
                            }
                            // a worker failed: stop draining the budget —
                            // the study is in an error state, running the
                            // remaining trials would mask it
                            budget.store(0, Ordering::SeqCst);
                            // keep the *first* error; later workers fail
                            // as a consequence and must not overwrite it
                            let mut slot = first_error.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            break;
                        }
                    })
                })
                .collect();
            for w in workers {
                w.join().expect("worker thread panicked");
            }
            stop_ticker.store(true, Ordering::SeqCst);
            if let Some(t) = ticker {
                t.join().expect("heartbeat ticker panicked");
            }
        });
        match first_error.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Evaluate a batch of asked trials and land the outcomes with one
    /// batched tell (the [`Study::optimize_parallel_batched`] worker
    /// body). Per-trial objective errors become recorded `Failed`/
    /// `Pruned` outcomes, not loop errors, matching `run_trial`; an
    /// outcome that fails conversion (arity misuse) is recorded as
    /// `Failed` too — nothing in the batch is left `Running` — and the
    /// first such error is surfaced after the batch lands.
    fn run_batch<F>(
        &self,
        trials: Vec<Trial<'_>>,
        objective: &F,
        heartbeats: Option<&HeartbeatRegistry>,
    ) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError>,
    {
        let ids: Vec<u64> = trials.iter().map(|t| t.id()).collect();
        let mut conversion_error = None;
        let mut finishes = Vec::with_capacity(trials.len());
        let mut fail_reasons: Vec<(u64, String)> = Vec::new();
        for mut trial in trials {
            let outcome = match catch_objective(|| objective(&mut trial)) {
                Ok(Ok(v)) if v.is_finite() => TrialOutcome::Complete(v),
                Ok(Ok(v)) => {
                    TrialOutcome::Failed(format!("non-finite objective value {v}"))
                }
                Ok(Err(OptunaError::TrialPruned)) => TrialOutcome::Pruned,
                Ok(Err(e)) => TrialOutcome::Failed(e.to_string()),
                Err(panic_msg) => {
                    TrialOutcome::Failed(format!("objective panicked: {panic_msg}"))
                }
            };
            match self.outcome_to_finish(&trial, outcome) {
                Ok((f, reason)) => {
                    if let Some(msg) = reason {
                        fail_reasons.push((f.trial_id, msg));
                    }
                    finishes.push(f);
                }
                Err(e) => {
                    // a misconfigured outcome (arity misuse) must not
                    // strand the rest of the batch as Running: record
                    // this trial as Failed, keep the first error to
                    // surface after the batch lands, keep converting
                    fail_reasons.push((trial.trial_id, e.to_string()));
                    finishes.push(TrialFinish {
                        trial_id: trial.trial_id,
                        state: TrialState::Failed,
                        values: Vec::new(),
                    });
                    if conversion_error.is_none() {
                        conversion_error = Some(e);
                    }
                }
            }
        }
        // land what converted even when a later conversion failed, so no
        // evaluated work is silently dropped; then surface the error
        self.record_fail_reasons(&fail_reasons);
        let landed = self.finish_batch(finishes);
        if let Some(reg) = heartbeats {
            for id in ids {
                reg.remove(id);
            }
        }
        match conversion_error {
            Some(e) => Err(e),
            None => landed,
        }
    }

    /// Fault-tolerant cooperative optimization: run trials until the
    /// study holds `target` finished non-failed (Complete or Pruned)
    /// trials — **across all workers and processes sharing the storage**.
    /// This is the distributed worker loop behind the CLI's
    /// `worker`/`distributed` commands: each process runs the same call
    /// against the same storage URL, and the shared budget is claimed
    /// atomically through [`Storage::create_trial_capped`], so the study
    /// finishes its exact budget even when workers crash mid-trial
    /// (their trials are reaped to `Failed`, releasing the slot, and —
    /// with failover configured — their configurations are re-enqueued
    /// and resumed by survivors).
    ///
    /// With a [`FailoverConfig`] set, a background ticker heartbeats the
    /// in-flight trial and every iteration reaps stale peers. Without
    /// one, the loop still cooperates on the budget but waits on peers'
    /// in-flight trials indefinitely (nothing is ever reaped).
    pub fn optimize_until<F>(&self, target: u64, objective: F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError> + Sync,
        Self: Sync,
    {
        let registry = HeartbeatRegistry::new();
        let stop_ticker = AtomicBool::new(false);
        let poll = self
            .failover
            .map(|cfg| cfg.heartbeat_interval)
            .unwrap_or(Duration::from_millis(25))
            .clamp(Duration::from_millis(5), Duration::from_millis(100));
        std::thread::scope(|scope| {
            let ticker = self.failover.map(|cfg| {
                let interval = cfg.heartbeat_interval;
                let (reg, stop) = (&registry, &stop_ticker);
                scope.spawn(move || self.heartbeat_loop(interval, reg, stop))
            });
            let run: Result<(), OptunaError> = (|| {
                // under failover, a transiently-unreachable store (past
                // the resilience layer's own retry budget) pauses the
                // loop instead of killing it: nothing claimed is lost —
                // the ask paths roll back on error and stranded peers'
                // trials go stale and are reaped on a later iteration
                let transient_pause = |e: OptunaError| -> Result<(), OptunaError> {
                    if self.failover.is_some() && e.is_transient() {
                        std::thread::sleep(poll);
                        Ok(())
                    } else {
                        Err(e)
                    }
                };
                loop {
                    if let Err(e) = self.reap_stale_trials() {
                        transient_pause(e)?;
                        continue;
                    }
                    let asked = match self.ask_capped_registered(target, Some(&registry)) {
                        Ok(asked) => asked,
                        Err(e) => {
                            transient_pause(e)?;
                            continue;
                        }
                    };
                    match asked {
                        Some(trial) => {
                            self.run_trial(trial, &objective, Some(&registry))?;
                        }
                        None => {
                            // budget fully claimed; done when it is all
                            // finished work, else wait on peers' trials
                            // (which either finish or go stale and are
                            // reaped on a later iteration)
                            let trials = match self
                                .storage
                                .get_trials_snapshot(self.study_id)
                            {
                                Ok(trials) => trials,
                                Err(e) => {
                                    transient_pause(e)?;
                                    continue;
                                }
                            };
                            let done = trials
                                .iter()
                                .filter(|t| {
                                    matches!(
                                        t.state,
                                        TrialState::Complete | TrialState::Pruned
                                    )
                                })
                                .count() as u64;
                            if done >= target {
                                return Ok(());
                            }
                            std::thread::sleep(poll);
                        }
                    }
                }
            })();
            stop_ticker.store(true, Ordering::SeqCst);
            if let Some(t) = ticker {
                t.join().expect("heartbeat ticker panicked");
            }
            run
        })
    }

    /// Multi-objective optimize loop: `objective` reports one value per
    /// objective, in [`Study::directions`] order. Pruned and failed
    /// trials are recorded, not fatal; a wrong-arity or non-finite vector
    /// fails the trial.
    ///
    /// ```
    /// use optuna_rs::prelude::*;
    /// use std::sync::Arc;
    ///
    /// let study = Study::builder()
    ///     .name("doc-moo")
    ///     .directions(&[StudyDirection::Minimize, StudyDirection::Minimize])
    ///     .sampler(Arc::new(NsgaIiSampler::new(0)))
    ///     .build()
    ///     .unwrap();
    /// study.optimize_multi(20, |trial| {
    ///     let x = trial.suggest_float("x", 0.0, 1.0)?;
    ///     Ok(vec![x, 1.0 - x])
    /// }).unwrap();
    /// assert!(!study.best_trials().unwrap().is_empty());
    /// assert!(study.best_value().is_err(), "no single best under 2 objectives");
    /// ```
    pub fn optimize_multi<F>(&self, n_trials: usize, objective: F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<Vec<f64>, OptunaError>,
    {
        for _ in 0..n_trials {
            self.run_one_multi(&objective)?;
        }
        Ok(())
    }

    /// Run one multi-objective trial (the [`Study::optimize_multi`] body).
    pub fn run_one_multi<F>(&self, objective: &F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<Vec<f64>, OptunaError>,
    {
        let mut trial = self.ask()?;
        let outcome = match catch_objective(|| objective(&mut trial)) {
            Ok(Ok(vs)) if vs.len() != self.n_objectives() => TrialOutcome::Failed(format!(
                "objective returned {} values, study has {} objectives",
                vs.len(),
                self.n_objectives()
            )),
            Ok(Ok(vs)) if vs.iter().all(|v| v.is_finite()) => {
                TrialOutcome::CompleteValues(vs)
            }
            Ok(Ok(vs)) => {
                TrialOutcome::Failed(format!("non-finite objective values {vs:?}"))
            }
            Ok(Err(OptunaError::TrialPruned)) => TrialOutcome::Pruned,
            Ok(Err(e)) => TrialOutcome::Failed(e.to_string()),
            Err(panic_msg) => {
                TrialOutcome::Failed(format!("objective panicked: {panic_msg}"))
            }
        };
        match self.tell(trial, outcome) {
            // same policy as run_trial: under failover, a reaped-by-peer
            // conflict (or a transiently-unreachable store — the reaper
            // will supersede the trial) means the work is not broken
            Err(e)
                if self.failover.is_some()
                    && (matches!(e, OptunaError::Conflict(_)) || e.is_transient()) =>
            {
                Ok(())
            }
            other => other,
        }
    }

    /// All trials, ordered by number.
    pub fn trials(&self) -> Result<Vec<FrozenTrial>, OptunaError> {
        self.storage.get_all_trials(self.study_id)
    }

    /// The resolved sampler's name (logs, dashboards; lets callers that
    /// built the study from a spec string confirm what they got).
    pub fn sampler_name(&self) -> &'static str {
        self.sampler.name()
    }

    /// The resolved pruner's name.
    pub fn pruner_name(&self) -> &'static str {
        self.pruner.name()
    }

    /// The Pareto front: completed trials whose objective vectors are not
    /// dominated by any other completed trial, ordered by trial number.
    /// On a single-objective study this degenerates to the best trial(s)
    /// (ties included). Trials whose recorded arity does not match the
    /// study (e.g. scalar records in a study later rebuilt as
    /// multi-objective) are not comparable and are excluded.
    ///
    /// When any candidate reported constraints
    /// ([`crate::trial::TrialApi::report_constraints`]) the front is
    /// computed under Deb's feasibility-aware dominance
    /// ([`crate::multi::dominates_constrained`]): any feasible trial
    /// beats every infeasible one, so the front is fully feasible
    /// whenever at least one feasible trial exists. Unconstrained
    /// studies are unaffected (all-zero violations reduce to plain
    /// Pareto dominance).
    pub fn best_trials(&self) -> Result<Vec<FrozenTrial>, OptunaError> {
        let trials = self.storage.get_trials_snapshot(self.study_id)?;
        let n_obj = self.n_objectives();
        let candidates: Vec<&FrozenTrial> = trials
            .iter()
            .filter(|t| {
                t.state == TrialState::Complete && t.objective_values().len() == n_obj
            })
            .collect();
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let losses: Vec<Vec<f64>> = candidates
            .iter()
            .map(|t| to_losses(&t.objective_values(), &self.directions))
            .collect();
        let fronts = if candidates.iter().any(|t| !t.constraints.is_empty()) {
            let violations: Vec<f64> =
                candidates.iter().map(|t| t.total_violation()).collect();
            nondominated_sort_constrained(&losses, &violations)
        } else {
            nondominated_sort(&losses)
        };
        let mut front: Vec<FrozenTrial> =
            fronts[0].iter().map(|&i| candidates[i].clone()).collect();
        front.sort_by_key(|t| t.number);
        Ok(front)
    }

    /// Exact hypervolume of the current Pareto front w.r.t. `ref_point`
    /// (given in raw objective space, one coordinate per objective —
    /// direction normalization happens internally). Supported for 1–3
    /// objectives; front members that do not strictly dominate the
    /// reference contribute nothing.
    pub fn hypervolume(&self, ref_point: &[f64]) -> Result<f64, OptunaError> {
        if ref_point.len() != self.n_objectives() {
            return Err(OptunaError::MultiObjective(format!(
                "reference point has {} coordinates, study has {} objectives",
                ref_point.len(),
                self.n_objectives()
            )));
        }
        let reference = to_losses(ref_point, &self.directions);
        let points: Vec<Vec<f64>> = self
            .best_trials()?
            .iter()
            .map(|t| to_losses(&t.objective_values(), &self.directions))
            .collect();
        crate::multi::hypervolume(&points, &reference)
    }

    /// Best completed trial under the study direction. Scans the shared
    /// snapshot and clones only the winner.
    ///
    /// NaN objective values (possible through the raw ask/tell API) rank
    /// *worst in both directions* via [`nan_max_cmp`] on the
    /// direction-normalized loss — the sampler/pruner convention. The
    /// naive `is_better` reduce was NaN-poisoned: `is_better(x, NaN)` is
    /// false both ways, so a NaN incumbent won forever.
    ///
    /// On a multi-objective study there is no single best trial: this
    /// returns a typed [`OptunaError::MultiObjective`] instead of
    /// silently ranking by objective 0 — use [`Study::best_trials`].
    pub fn best_trial(&self) -> Result<Option<FrozenTrial>, OptunaError> {
        if self.is_multi_objective() {
            return Err(OptunaError::MultiObjective(format!(
                "best_trial on a {}-objective study — use best_trials (the Pareto front)",
                self.n_objectives()
            )));
        }
        let trials = self.storage.get_trials_snapshot(self.study_id)?;
        let sign = self.direction.min_sign();
        Ok(trials
            .iter()
            .filter(|t| t.state == TrialState::Complete && t.value.is_some())
            .reduce(|best, t| {
                let candidate = sign * t.value.unwrap();
                let incumbent = sign * best.value.unwrap();
                if nan_max_cmp(&candidate, &incumbent) == std::cmp::Ordering::Less {
                    t
                } else {
                    best
                }
            })
            .cloned())
    }

    /// Best objective value, if any trial completed.
    pub fn best_value(&self) -> Result<Option<f64>, OptunaError> {
        Ok(self.best_trial()?.and_then(|t| t.value))
    }

    /// Export the trial table as CSV (the pandas-dataframe analog, §4).
    /// Single-objective studies keep the historical `value` header;
    /// multi-objective studies emit one `value_<i>` column per objective.
    pub fn to_csv(&self) -> Result<String, OptunaError> {
        Ok(trials_to_csv(&self.trials()?, self.n_objectives()))
    }

    /// CSV of the Pareto front only (the CLI `pareto --out` export).
    pub fn front_to_csv(&self) -> Result<String, OptunaError> {
        Ok(trials_to_csv(&self.best_trials()?, self.n_objectives()))
    }
}

/// RFC-4180 field quoting: a field containing a comma, double quote, CR
/// or LF is wrapped in double quotes with embedded quotes doubled. All
/// other fields are emitted verbatim, which keeps the historical byte
/// format for the (numeric / plain-identifier) common case.
fn csv_field(s: &str) -> String {
    if s.chars().any(|c| matches!(c, ',' | '"' | '\n' | '\r')) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Shared CSV writer behind [`Study::to_csv`] / [`Study::front_to_csv`]
/// (and the CLI `pareto` export, which passes an already-computed front).
/// `n_objectives == 1` must stay byte-identical to the pre-multi format
/// (regression-tested): header `number,state,value`, empty cell for
/// valueless trials. String content (parameter names, categorical
/// values) is RFC-4180 quoted via [`csv_field`] so commas, quotes and
/// newlines cannot shear the row grid.
pub(crate) fn trials_to_csv(trials: &[FrozenTrial], n_objectives: usize) -> String {
    // union of parameter names, ordered
    let mut names: Vec<String> = Vec::new();
    for t in trials {
        for k in t.params.keys() {
            if !names.contains(k) {
                names.push(k.clone());
            }
        }
    }
    names.sort();
    let mut out = String::from("number,state");
    if n_objectives == 1 {
        out.push_str(",value");
    } else {
        for i in 0..n_objectives {
            out.push_str(&format!(",value_{i}"));
        }
    }
    for n in &names {
        out.push(',');
        out.push_str(&csv_field(n));
    }
    out.push('\n');
    for t in trials {
        out.push_str(&format!("{},{}", t.number, t.state.as_str()));
        if n_objectives == 1 {
            out.push(',');
            if let Some(v) = t.value {
                out.push_str(&v.to_string());
            }
        } else {
            let values = t.objective_values();
            for i in 0..n_objectives {
                out.push(',');
                // wrong-arity records (scalar rows in a multi study) leave
                // their cells empty rather than guessing an alignment
                if values.len() == n_objectives {
                    out.push_str(&values[i].to_string());
                }
            }
        }
        for n in &names {
            out.push(',');
            if let Some(v) = t.param(n) {
                out.push_str(&csv_field(&v.to_string()));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ParamValue;
    use crate::pruner::AshaPruner;
    use crate::sampler::RandomSampler;
    use crate::trial::TrialApi;

    fn quadratic_study(seed: u64) -> Study {
        Study::builder()
            .name("quad")
            .sampler(Arc::new(RandomSampler::new(seed)))
            .build()
            .unwrap()
    }

    #[test]
    fn optimize_records_trials_and_best() {
        let study = quadratic_study(0);
        study
            .optimize(50, |t| {
                let x = t.suggest_float("x", -5.0, 5.0)?;
                Ok(x * x)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 50);
        assert!(trials.iter().all(|t| t.state == TrialState::Complete));
        let best = study.best_trial().unwrap().unwrap();
        assert!(best.value.unwrap() < 1.0, "best={:?}", best.value);
        match best.param("x").unwrap() {
            ParamValue::Float(x) => {
                assert!((x * x - best.value.unwrap()).abs() < 1e-9)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn dynamic_conditional_space() {
        // Fig 3 analog: branch on a categorical; params exist per-branch.
        let study = quadratic_study(1);
        study
            .optimize(40, |t| {
                let kind = t.suggest_categorical("model", &["linear", "mlp"])?;
                if kind == "mlp" {
                    let n_layers = t.suggest_int("n_layers", 1, 3)?;
                    let mut total = 0.0;
                    for i in 0..n_layers {
                        total += t.suggest_int(&format!("units_l{i}"), 4, 64)? as f64;
                    }
                    Ok(total / 64.0)
                } else {
                    let reg = t.suggest_float_log("reg", 1e-5, 1.0)?;
                    Ok(reg.ln().abs() / 10.0)
                }
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 40);
        let mlps = trials
            .iter()
            .filter(|t| t.param("model") == Some(ParamValue::Cat("mlp".into())))
            .count();
        assert!(mlps > 5 && mlps < 35, "mlps={mlps}");
        // branch params only exist where taken
        for t in &trials {
            let is_mlp = t.param("model") == Some(ParamValue::Cat("mlp".into()));
            assert_eq!(t.params.contains_key("n_layers"), is_mlp);
            assert_eq!(t.params.contains_key("reg"), !is_mlp);
        }
    }

    #[test]
    fn resuggest_same_name_is_idempotent() {
        let study = quadratic_study(2);
        study
            .optimize(3, |t| {
                let a = t.suggest_float("x", 0.0, 1.0)?;
                let b = t.suggest_float("x", 0.0, 1.0)?;
                assert_eq!(a, b);
                // changing the distribution mid-trial is an error
                assert!(t.suggest_float("x", 0.0, 2.0).is_err());
                Ok(a)
            })
            .unwrap();
    }

    #[test]
    fn failed_trials_recorded_not_fatal() {
        let study = quadratic_study(3);
        study
            .optimize(10, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                if x < 0.5 {
                    Err(OptunaError::Objective("boom".into()))
                } else {
                    Ok(x)
                }
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 10);
        let failed = trials.iter().filter(|t| t.state == TrialState::Failed).count();
        assert!(failed > 0);
        assert!(trials
            .iter()
            .filter(|t| t.state == TrialState::Failed)
            .all(|t| t.user_attrs.contains_key("fail_reason")));
    }

    #[test]
    fn non_finite_objective_fails_trial() {
        let study = quadratic_study(4);
        study.optimize(2, |_t| Ok(f64::NAN)).unwrap();
        assert!(study
            .trials()
            .unwrap()
            .iter()
            .all(|t| t.state == TrialState::Failed));
    }

    #[test]
    fn pruning_loop_fig5() {
        // Fig 5 pattern: report + should_prune inside iterative training.
        let study = Study::builder()
            .name("pruned")
            .sampler(Arc::new(RandomSampler::new(5)))
            .pruner(Arc::new(AshaPruner::new()))
            .build()
            .unwrap();
        study
            .optimize(60, |t| {
                let lr = t.suggest_float("lr", 0.0, 1.0)?;
                // simple synthetic curve: bad lr ⇒ high plateau
                let mut v = 1.0;
                for step in 1..=16u64 {
                    v = (lr - 0.3).abs() + 1.0 / step as f64;
                    t.report(step, v)?;
                    if t.should_prune()? {
                        return Err(OptunaError::TrialPruned);
                    }
                }
                Ok(v)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        let pruned = trials.iter().filter(|t| t.state == TrialState::Pruned).count();
        let complete = trials.iter().filter(|t| t.state == TrialState::Complete).count();
        assert!(pruned > 10, "pruned={pruned}");
        assert!(complete > 0);
        // pruned trials carry their last intermediate as value
        assert!(trials
            .iter()
            .filter(|t| t.state == TrialState::Pruned)
            .all(|t| t.value.is_some()));
    }

    #[test]
    fn parallel_optimize_shares_history() {
        let study = quadratic_study(6);
        study
            .optimize_parallel(64, 8, |t| {
                let x = t.suggest_float("x", -5.0, 5.0)?;
                Ok(x * x)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 64);
        let mut numbers: Vec<u64> = trials.iter().map(|t| t.number).collect();
        numbers.sort_unstable();
        assert_eq!(numbers, (0..64).collect::<Vec<u64>>());
    }

    #[test]
    fn ask_batch_pops_waiting_then_creates_fresh() {
        let study = quadratic_study(41);
        let d = crate::core::Distribution::float(0.0, 1.0);
        let mut params = crate::storage::ParamSet::new();
        params.insert("x".into(), (d, 0.25));
        study
            .storage
            .enqueue_trial(study.study_id, &params, &BTreeMap::new())
            .unwrap();
        let mut batch = study.ask_batch(3).unwrap();
        assert_eq!(batch.len(), 3);
        // the queued configuration resumes first and replays its value
        assert_eq!(batch[0].suggest_float("x", 0.0, 1.0).unwrap(), 0.25);
        let values: Vec<f64> = batch
            .iter_mut()
            .map(|t| t.suggest_float("x", 0.0, 1.0).unwrap())
            .collect();
        let told: Vec<(Trial<'_>, TrialOutcome)> = batch
            .into_iter()
            .zip(values)
            .map(|(t, v)| (t, TrialOutcome::Complete(v)))
            .collect();
        study.tell_batch(told).unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 3);
        assert!(trials.iter().all(|t| t.state == TrialState::Complete));
        assert_eq!(trials[0].value, Some(0.25));
    }

    #[test]
    fn tell_batch_mixed_outcomes() {
        let study = quadratic_study(42);
        let mut batch = study.ask_batch(3).unwrap();
        batch[1].report(1, 0.7).unwrap();
        let outcomes = vec![
            TrialOutcome::Complete(1.0),
            TrialOutcome::Pruned,
            TrialOutcome::Failed("boom".into()),
        ];
        study
            .tell_batch(batch.into_iter().zip(outcomes).collect())
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials[0].state, TrialState::Complete);
        assert_eq!(trials[0].value, Some(1.0));
        assert_eq!(trials[1].state, TrialState::Pruned);
        assert_eq!(trials[1].value, Some(0.7), "pruned carries its last report");
        assert_eq!(trials[2].state, TrialState::Failed);
        assert_eq!(trials[2].user_attrs["fail_reason"], "boom");
    }

    #[test]
    fn tell_batch_arity_error_leaves_batch_untold() {
        let study = moo_study(44);
        let batch = study.ask_batch(2).unwrap();
        // a valid Failed outcome followed by an arity-violating Complete:
        // the rejection must write NOTHING — not even the failure's
        // fail_reason attribute
        let mut outcomes = vec![
            TrialOutcome::Failed("late loser".into()),
            TrialOutcome::Complete(1.0),
        ];
        let err = study
            .tell_batch(batch.into_iter().zip(outcomes.drain(..)).collect())
            .unwrap_err();
        assert!(matches!(err, OptunaError::MultiObjective(_)), "{err}");
        for t in study.trials().unwrap() {
            assert_eq!(t.state, TrialState::Running);
            assert!(
                !t.user_attrs.contains_key("fail_reason"),
                "rejected batch must not leak fail_reason attrs"
            );
        }
    }

    #[test]
    fn optimize_parallel_batched_arity_misuse_fails_cleanly() {
        // a scalar objective on a multi-objective study: the worker loop
        // must surface the typed error AND leave no trial stranded
        // Running (every asked trial is recorded Failed)
        let study = moo_study(45);
        let err = study
            .optimize_parallel_batched(8, 2, 4, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(x)
            })
            .unwrap_err();
        assert!(matches!(err, OptunaError::MultiObjective(_)), "{err}");
        let trials = study.trials().unwrap();
        assert!(!trials.is_empty());
        assert!(
            trials.iter().all(|t| t.state == TrialState::Failed),
            "no trial may stay Running after an arity-misuse batch"
        );
    }

    #[test]
    fn optimize_parallel_batched_exact_budget() {
        let study = quadratic_study(43);
        study
            .optimize_parallel_batched(30, 4, 8, |t| {
                let x = t.suggest_float("x", -1.0, 1.0)?;
                Ok(x * x)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 30, "batch claims must drain the budget exactly");
        let mut numbers: Vec<u64> = trials.iter().map(|t| t.number).collect();
        numbers.sort_unstable();
        assert_eq!(numbers, (0..30).collect::<Vec<u64>>());
        assert!(trials.iter().all(|t| t.state == TrialState::Complete));
    }

    #[test]
    fn csv_rfc4180_escapes_commas_quotes_newlines() {
        // Byte-level regression: string content with CSV metacharacters
        // must be quoted per RFC 4180 (quotes doubled), while plain rows
        // keep the historical unquoted format.
        let study = quadratic_study(40);
        let dist = crate::core::Distribution::categorical(vec![
            "plain",
            "a,b",
            "he said \"hi\"",
            "line\nbreak",
        ]);
        for (internal, value) in [(1.0, 0.5), (2.0, 1.5), (3.0, 2.5), (0.0, 3.5)] {
            let t = study.ask().unwrap();
            let tid = t.id();
            study
                .storage
                .set_trial_param(tid, "choice,col", &dist, internal)
                .unwrap();
            study.tell(t, TrialOutcome::Complete(value)).unwrap();
        }
        assert_eq!(
            study.to_csv().unwrap(),
            "number,state,value,\"choice,col\"\n\
             0,complete,0.5,\"a,b\"\n\
             1,complete,1.5,\"he said \"\"hi\"\"\"\n\
             2,complete,2.5,\"line\nbreak\"\n\
             3,complete,3.5,plain\n"
        );
    }

    #[test]
    fn cached_and_uncached_storage_agree() {
        // same seed, caching on vs off: identical trajectories
        let run = |cached: bool| -> Vec<Option<f64>> {
            let study = Study::builder()
                .name("cache-eq")
                .sampler(Arc::new(RandomSampler::new(11)))
                .storage_caching(cached)
                .build()
                .unwrap();
            study
                .optimize(25, |t| {
                    let x = t.suggest_float("x", -1.0, 1.0)?;
                    t.report(1, x)?;
                    Ok(x)
                })
                .unwrap();
            study.trials().unwrap().into_iter().map(|t| t.value).collect()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn builder_wraps_storage_in_cache_by_default() {
        let study = quadratic_study(12);
        assert!(study.storage.is_write_through_cache());
        let raw = Study::builder()
            .name("raw")
            .storage_caching(false)
            .build()
            .unwrap();
        assert!(!raw.storage.is_write_through_cache());
    }

    #[test]
    fn builder_observation_index_default_on_and_optional() {
        let study = quadratic_study(13);
        assert!(study.obs_index.is_some());
        let plain = Study::builder()
            .name("no-index")
            .observation_index(false)
            .build()
            .unwrap();
        assert!(plain.obs_index.is_none());
        assert!(plain.sync_obs_index().unwrap().is_none());
    }

    #[test]
    fn obs_index_tracks_study_through_optimize() {
        let study = Study::builder()
            .name("idx-sync")
            .sampler(Arc::new(RandomSampler::new(14)))
            .build()
            .unwrap();
        study
            .optimize(12, |t| {
                let x = t.suggest_float("x", -1.0, 1.0)?;
                t.report(1, x)?;
                Ok(x)
            })
            .unwrap();
        let snap = study.sync_obs_index().unwrap().unwrap();
        assert_eq!(snap.n_finished(), 12);
        let d = crate::core::Distribution::float(-1.0, 1.0);
        let col = snap.param_column("x", &d).unwrap();
        assert_eq!(col.len(), 12);
        // losses come out ascending
        for w in col.values_by_loss().windows(2) {
            assert!(w[0] <= w[1], "losses (=values here) must ascend");
        }
        assert_eq!(snap.step_column(1).unwrap().len(), 12);
        // quiet study: repeated syncs share the same snapshot Arc
        let again = study.sync_obs_index().unwrap().unwrap();
        assert!(Arc::ptr_eq(&snap, &again));
    }

    #[test]
    fn ask_tell_api() {
        let study = quadratic_study(7);
        let mut t = study.ask().unwrap();
        let x = t.suggest_float("x", 0.0, 1.0).unwrap();
        study.tell(t, TrialOutcome::Complete(x)).unwrap();
        let t2 = study.ask().unwrap();
        assert_eq!(t2.number(), 1);
        study.tell(t2, TrialOutcome::Failed("skip".into())).unwrap();
        assert_eq!(study.trials().unwrap().len(), 2);
        assert_eq!(study.best_value().unwrap(), Some(x));
    }

    #[test]
    fn csv_export_contains_params() {
        let study = quadratic_study(8);
        study
            .optimize(5, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                let c = t.suggest_categorical("c", &["a", "b"])?;
                Ok(x + if c == "a" { 0.0 } else { 1.0 })
            })
            .unwrap();
        let csv = study.to_csv().unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 6);
        assert!(lines[0].starts_with("number,state,value"));
        assert!(lines[0].contains(",c") && lines[0].contains(",x"));
    }

    /// Storage decorator whose `finish_trial` starts failing permanently
    /// after `fail_after` successful finishes. The first failure is
    /// "primary failure"; later ones stall 100ms inside the storage and
    /// fail as "secondary failure" — so the regression test below can
    /// tell whether `optimize_parallel` kept the chronologically first
    /// error or let a follower overwrite it.
    struct FailingFinish {
        inner: InMemoryStorage,
        finishes: AtomicUsize,
        fail_after: usize,
    }

    impl Storage for FailingFinish {
        fn create_study(
            &self,
            n: &str,
            d: StudyDirection,
        ) -> Result<u64, OptunaError> {
            self.inner.create_study(n, d)
        }
        fn get_study_id(&self, n: &str) -> Result<Option<u64>, OptunaError> {
            self.inner.get_study_id(n)
        }
        fn get_study_direction(&self, s: u64) -> Result<StudyDirection, OptunaError> {
            self.inner.get_study_direction(s)
        }
        fn study_names(&self) -> Result<Vec<String>, OptunaError> {
            self.inner.study_names()
        }
        fn create_trial(&self, s: u64) -> Result<(u64, u64), OptunaError> {
            self.inner.create_trial(s)
        }
        fn set_trial_param(
            &self,
            t: u64,
            n: &str,
            d: &crate::core::Distribution,
            v: f64,
        ) -> Result<(), OptunaError> {
            self.inner.set_trial_param(t, n, d, v)
        }
        fn set_trial_intermediate(&self, t: u64, s: u64, v: f64) -> Result<(), OptunaError> {
            self.inner.set_trial_intermediate(t, s, v)
        }
        fn set_trial_user_attr(&self, t: u64, k: &str, v: &str) -> Result<(), OptunaError> {
            self.inner.set_trial_user_attr(t, k, v)
        }
        fn finish_trial(
            &self,
            t: u64,
            st: TrialState,
            v: Option<f64>,
        ) -> Result<(), OptunaError> {
            let n = self.finishes.fetch_add(1, Ordering::SeqCst);
            if n < self.fail_after {
                return self.inner.finish_trial(t, st, v);
            }
            if n == self.fail_after {
                Err(OptunaError::Storage("primary failure".into()))
            } else {
                std::thread::sleep(Duration::from_millis(100));
                Err(OptunaError::Storage("secondary failure".into()))
            }
        }
        fn get_trial(&self, t: u64) -> Result<FrozenTrial, OptunaError> {
            self.inner.get_trial(t)
        }
        fn get_all_trials(&self, s: u64) -> Result<Vec<FrozenTrial>, OptunaError> {
            self.inner.get_all_trials(s)
        }
        fn n_trials(&self, s: u64) -> Result<usize, OptunaError> {
            self.inner.n_trials(s)
        }
    }

    #[test]
    fn parallel_worker_error_stops_budget_and_keeps_first_error() {
        let storage = Arc::new(FailingFinish {
            inner: InMemoryStorage::new(),
            finishes: AtomicUsize::new(0),
            fail_after: 2,
        });
        let study = Study::builder()
            .name("boom")
            .storage(storage)
            .sampler(Arc::new(RandomSampler::new(0)))
            .build()
            .unwrap();
        let err = study
            .optimize_parallel(1000, 4, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(x)
            })
            .unwrap_err();
        assert!(
            err.to_string().contains("primary failure"),
            "the first worker error must be preserved, got: {err}"
        );
        // the budget must be zeroed on error: without the fix all 1000
        // slots keep draining after the failure
        let n = study.trials().unwrap().len();
        assert!(n < 100, "budget kept draining after worker error: {n} trials ran");
    }

    #[test]
    fn nan_complete_trial_does_not_poison_best() {
        let study = quadratic_study(20);
        // NaN lands first, so the naive reduce would keep it forever
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::Complete(f64::NAN)).unwrap();
        let mut t = study.ask().unwrap();
        let x = t.suggest_float("x", 0.0, 1.0).unwrap();
        study.tell(t, TrialOutcome::Complete(5.0)).unwrap();
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::Complete(f64::NAN)).unwrap();
        let best = study.best_trial().unwrap().unwrap();
        assert_eq!(best.value, Some(5.0), "NaN must rank worst under minimize");
        let _ = x;

        let study = Study::builder()
            .name("nan-max")
            .direction(StudyDirection::Maximize)
            .build()
            .unwrap();
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::Complete(f64::NAN)).unwrap();
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::Complete(-3.0)).unwrap();
        assert_eq!(
            study.best_value().unwrap(),
            Some(-3.0),
            "NaN must rank worst under maximize too"
        );
    }

    #[test]
    fn ask_pops_waiting_trials_first_and_replays_params() {
        let study = quadratic_study(21);
        let mut params = crate::storage::ParamSet::new();
        let d = crate::core::Distribution::float(0.0, 1.0);
        params.insert("x".into(), (d, 0.25));
        let mut attrs = BTreeMap::new();
        attrs.insert("retry_count".to_string(), "1".to_string());
        study.storage.enqueue_trial(study.study_id, &params, &attrs).unwrap();

        let mut t = study.ask().unwrap();
        assert_eq!(t.suggest_float("x", 0.0, 1.0).unwrap(), 0.25, "replays enqueued value");
        // same name under a different distribution is rejected, as in any
        // live trial
        assert!(t.suggest_float("x", 0.0, 2.0).is_err());
        study.tell(t, TrialOutcome::Complete(0.25)).unwrap();

        // queue drained: the next ask creates a fresh trial
        let t2 = study.ask().unwrap();
        assert_eq!(t2.number(), 1);
        study.tell(t2, TrialOutcome::Failed("skip".into())).unwrap();

        let trials = study.trials().unwrap();
        assert_eq!(trials[0].state, TrialState::Complete);
        assert_eq!(trials[0].value, Some(0.25));
        assert_eq!(trials[0].retry_count(), 1);
    }

    #[test]
    fn stale_trials_reaped_and_retried_up_to_max_retry() {
        let study = Study::builder()
            .name("failover")
            .sampler(Arc::new(RandomSampler::new(22)))
            .failover(FailoverConfig {
                heartbeat_interval: Duration::from_millis(10),
                grace: Duration::from_millis(30),
                max_retry: 1,
            })
            .build()
            .unwrap();
        // a worker that died mid-trial: asked + suggested, never told
        let mut dead = study.ask().unwrap();
        let x = dead.suggest_float("x", -1.0, 1.0).unwrap();
        let dead_id = dead.id();
        drop(dead);
        std::thread::sleep(Duration::from_millis(50));

        let victims = study.reap_stale_trials().unwrap();
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].id, dead_id);
        assert_eq!(victims[0].state, TrialState::Failed);

        // the configuration waits in the queue; ask resumes it verbatim
        let mut retry = study.ask().unwrap();
        assert_eq!(retry.suggest_float("x", -1.0, 1.0).unwrap(), x);
        let retry_id = retry.id();
        drop(retry); // ... and dies again
        std::thread::sleep(Duration::from_millis(50));

        let victims = study.reap_stale_trials().unwrap();
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].id, retry_id);
        assert_eq!(victims[0].retry_count(), 1);
        // max_retry exhausted: nothing re-enqueued
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 2);
        assert!(trials.iter().all(|t| t.state != TrialState::Waiting));
    }

    #[test]
    fn retry_callback_can_veto_requeue() {
        let study = Study::builder()
            .name("veto")
            .failover(FailoverConfig {
                heartbeat_interval: Duration::from_millis(10),
                grace: Duration::from_millis(20),
                max_retry: 5,
            })
            .retry_callback(|_| false)
            .build()
            .unwrap();
        let t = study.ask().unwrap();
        drop(t);
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(study.reap_stale_trials().unwrap().len(), 1);
        assert!(study
            .trials()
            .unwrap()
            .iter()
            .all(|t| t.state != TrialState::Waiting));
    }

    #[test]
    fn optimize_until_finishes_exact_budget_despite_stranded_peer() {
        let study = Study::builder()
            .name("until")
            .sampler(Arc::new(RandomSampler::new(23)))
            .failover(FailoverConfig {
                heartbeat_interval: Duration::from_millis(10),
                // generous vs. the instant objective below, so a slow CI
                // box cannot false-reap the live retry mid-run
                grace: Duration::from_millis(150),
                max_retry: 3,
            })
            .build()
            .unwrap();
        // a "dead peer" left a parameterized Running trial behind
        let mut dead = study.ask().unwrap();
        dead.suggest_float("x", -5.0, 5.0).unwrap();
        drop(dead);
        std::thread::sleep(Duration::from_millis(200));

        study
            .optimize_until(6, |t| {
                let x = t.suggest_float("x", -5.0, 5.0)?;
                Ok(x * x)
            })
            .unwrap();

        let trials = study.trials().unwrap();
        let complete = trials.iter().filter(|t| t.state == TrialState::Complete).count();
        assert_eq!(complete, 6, "exact budget of finished trials");
        assert!(trials
            .iter()
            .all(|t| !matches!(t.state, TrialState::Running | TrialState::Waiting)));
        // the stranded trial was reaped, and its exact configuration retried
        let failed: Vec<_> =
            trials.iter().filter(|t| t.state == TrialState::Failed).collect();
        assert_eq!(failed.len(), 1);
        assert!(failed[0].user_attrs.contains_key("fail_reason"));
        let retried = trials.iter().find(|t| {
            t.user_attrs.get("retried_from") == Some(&failed[0].number.to_string())
        });
        let retried = retried.expect("the victim's configuration must be retried");
        assert_eq!(retried.state, TrialState::Complete);
        assert_eq!(
            retried.param_internal("x"),
            failed[0].param_internal("x"),
            "the retry resumes the victim's parameters verbatim"
        );
    }

    #[test]
    fn single_objective_csv_is_byte_identical_to_pre_multi_format() {
        // Regression gate for the ISSUE 4 satellite: the multi-objective
        // CSV rework must not change a single byte of single-objective
        // exports. Deterministic rows via the enqueue-replay path.
        let study = quadratic_study(30);
        let d = crate::core::Distribution::float(0.0, 1.0);
        let mut params = crate::storage::ParamSet::new();
        params.insert("x".into(), (d, 0.25));
        study.storage.enqueue_trial(study.study_id, &params, &BTreeMap::new()).unwrap();
        let mut t = study.ask().unwrap();
        let x = t.suggest_float("x", 0.0, 1.0).unwrap();
        assert_eq!(x, 0.25);
        study.tell(t, TrialOutcome::Complete(0.25)).unwrap();
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::Failed("skip".into())).unwrap();
        assert_eq!(
            study.to_csv().unwrap(),
            "number,state,value,x\n0,complete,0.25,0.25\n1,failed,,\n"
        );
    }

    fn moo_study(seed: u64) -> Study {
        Study::builder()
            .name("moo")
            .directions(&[StudyDirection::Minimize, StudyDirection::Minimize])
            .sampler(Arc::new(RandomSampler::new(seed)))
            .build()
            .unwrap()
    }

    #[test]
    fn multi_objective_end_to_end() {
        let study = moo_study(31);
        assert_eq!(study.n_objectives(), 2);
        assert!(study.is_multi_objective());
        study
            .optimize_multi(40, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(vec![x, 1.0 - x]) // a perfect linear trade-off
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 40);
        assert!(trials.iter().all(|t| t.state == TrialState::Complete));
        assert!(trials.iter().all(|t| t.objective_values().len() == 2));
        // every point sits on the trade-off line, so ALL are nondominated
        let front = study.best_trials().unwrap();
        assert_eq!(front.len(), 40);
        // the front is mutually nondominated
        let losses: Vec<Vec<f64>> =
            front.iter().map(|t| t.objective_values()).collect();
        for (i, a) in losses.iter().enumerate() {
            for b in &losses[i + 1..] {
                assert!(
                    !crate::multi::dominates(a, b) && !crate::multi::dominates(b, a),
                    "front members dominate each other: {a:?} vs {b:?}"
                );
            }
        }
        // hypervolume of the x + (1-x) front w.r.t. (1.1, 1.1) is below
        // the 1.21 box but comfortably above the single-corner value
        let hv = study.hypervolume(&[1.1, 1.1]).unwrap();
        assert!(hv > 0.5 && hv < 1.21, "hv={hv}");
    }

    #[test]
    fn multi_objective_dominated_points_excluded_from_front() {
        let study = moo_study(32);
        let cases: &[(f64, f64)] = &[(0.1, 0.9), (0.9, 0.1), (0.5, 0.5), (0.6, 0.6)];
        for &(a, b) in cases {
            let t = study.ask().unwrap();
            study.tell(t, TrialOutcome::CompleteValues(vec![a, b])).unwrap();
        }
        let front = study.best_trials().unwrap();
        let numbers: Vec<u64> = front.iter().map(|t| t.number).collect();
        assert_eq!(numbers, vec![0, 1, 2], "(0.6, 0.6) is dominated by (0.5, 0.5)");
        // direction-aware: rebuild as maximize/maximize flips the front
        let study = Study::builder()
            .name("moo-max")
            .directions(&[StudyDirection::Maximize, StudyDirection::Maximize])
            .build()
            .unwrap();
        for &(a, b) in cases {
            let t = study.ask().unwrap();
            study.tell(t, TrialOutcome::CompleteValues(vec![a, b])).unwrap();
        }
        let numbers: Vec<u64> =
            study.best_trials().unwrap().iter().map(|t| t.number).collect();
        assert_eq!(numbers, vec![0, 1, 3], "(0.5, 0.5) is dominated by (0.6, 0.6)");
    }

    #[test]
    fn best_trial_and_best_value_are_typed_errors_on_multi_study() {
        let study = moo_study(33);
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::CompleteValues(vec![1.0, 2.0])).unwrap();
        assert!(matches!(study.best_trial(), Err(OptunaError::MultiObjective(_))));
        assert!(matches!(study.best_value(), Err(OptunaError::MultiObjective(_))));
        // the front accessor is the supported path
        assert_eq!(study.best_trials().unwrap().len(), 1);
    }

    #[test]
    fn tell_arity_mismatches_are_typed_errors() {
        let study = moo_study(34);
        let t = study.ask().unwrap();
        let err = study.tell(t, TrialOutcome::Complete(1.0)).unwrap_err();
        assert!(matches!(err, OptunaError::MultiObjective(_)), "{err}");
        let t = study.ask().unwrap();
        let err = study
            .tell(t, TrialOutcome::CompleteValues(vec![1.0, 2.0, 3.0]))
            .unwrap_err();
        assert!(matches!(err, OptunaError::MultiObjective(_)), "{err}");
        // arity-checked tells leave the trials untold (still running)
        assert!(study.trials().unwrap().iter().all(|t| t.state == TrialState::Running));
        // wrong-arity *objective* fails the trial instead of aborting the loop
        study.optimize_multi(2, |_t| Ok(vec![1.0])).unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(
            trials.iter().filter(|t| t.state == TrialState::Failed).count(),
            2
        );
        // single-objective studies accept a 1-vector through the same API
        let single = quadratic_study(35);
        let t = single.ask().unwrap();
        single.tell(t, TrialOutcome::CompleteValues(vec![0.5])).unwrap();
        assert_eq!(single.best_value().unwrap(), Some(0.5));
    }

    #[test]
    fn multi_csv_emits_one_value_column_per_objective() {
        let study = moo_study(36);
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::CompleteValues(vec![0.25, 4.0])).unwrap();
        let t = study.ask().unwrap();
        study.tell(t, TrialOutcome::Failed("skip".into())).unwrap();
        let csv = study.to_csv().unwrap();
        assert_eq!(csv, "number,state,value_0,value_1\n0,complete,0.25,4\n1,failed,,\n");
        let front_csv = study.front_to_csv().unwrap();
        assert_eq!(front_csv, "number,state,value_0,value_1\n0,complete,0.25,4\n");
    }

    #[test]
    fn hypervolume_checks_reference_arity() {
        let study = moo_study(37);
        assert!(matches!(
            study.hypervolume(&[1.0]),
            Err(OptunaError::MultiObjective(_))
        ));
        // empty study: zero volume, not an error
        assert_eq!(study.hypervolume(&[1.0, 1.0]).unwrap(), 0.0);
    }

    #[test]
    fn maximize_direction_best() {
        let study = Study::builder()
            .name("max")
            .direction(StudyDirection::Maximize)
            .sampler(Arc::new(RandomSampler::new(9)))
            .build()
            .unwrap();
        study
            .optimize(30, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(x)
            })
            .unwrap();
        assert!(study.best_value().unwrap().unwrap() > 0.8);
    }

    #[test]
    fn heartbeat_registry_recovers_from_poisoning() {
        // A thread dying while holding the registry lock must not turn
        // off heartbeats for the *surviving* workers' trials.
        let reg = HeartbeatRegistry::new();
        reg.insert(1);
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = reg.trials.lock().unwrap();
            panic!("worker died mid-registration");
        }));
        assert!(poison.is_err());
        assert!(reg.trials.lock().is_err(), "the mutex really is poisoned");
        reg.insert(2);
        reg.remove(1);
        assert_eq!(reg.ids(), vec![2]);
    }

    #[test]
    fn panicking_objective_is_recorded_not_fatal() {
        let study = Study::builder()
            .name("panicky")
            .sampler(Arc::new(RandomSampler::new(5)))
            .failover(FailoverConfig {
                heartbeat_interval: Duration::from_millis(5),
                grace: Duration::from_millis(500),
                max_retry: 0,
            })
            .build()
            .unwrap();
        let n = AtomicUsize::new(0);
        // two of six objective evaluations panic (deterministically, via
        // the shared counter); the loop — heartbeat ticker included —
        // must survive them and finish the full budget
        study
            .optimize_parallel(6, 2, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                if n.fetch_add(1, Ordering::SeqCst) % 3 == 0 {
                    panic!("boom at x={x}");
                }
                Ok(x)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 6);
        assert!(
            trials
                .iter()
                .all(|t| !matches!(t.state, TrialState::Running | TrialState::Waiting)),
            "a panicking objective must not strand its trial"
        );
        let failed: Vec<_> =
            trials.iter().filter(|t| t.state == TrialState::Failed).collect();
        assert_eq!(failed.len(), 2);
        for t in &failed {
            let reason = t.user_attrs.get("fail_reason").expect("panic must be recorded");
            assert!(reason.contains("objective panicked"), "{reason}");
            assert!(reason.contains("boom at"), "{reason}");
        }
        // the same study object keeps working after the panics
        study
            .optimize(2, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(x)
            })
            .unwrap();
        assert_eq!(study.trials().unwrap().len(), 8);
    }

    #[test]
    fn sampler_spec_resolves_through_registry() {
        let study = Study::builder()
            .name("spec")
            .sampler_spec("tpe:n_startup=3,candidates=8")
            .pruner_spec("asha:reduction=3")
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(study.sampler.name(), "tpe");
        assert_eq!(study.pruner.name(), "asha");
        study
            .optimize(8, |t| {
                let x = t.suggest_float("x", -1.0, 1.0)?;
                Ok(x * x)
            })
            .unwrap();
        assert_eq!(study.trials().unwrap().len(), 8);
    }

    #[test]
    fn sampler_spec_errors_are_typed_and_enumerate_names() {
        let err = Study::builder()
            .name("spec-bad")
            .sampler_spec("genetic")
            .build()
            .unwrap_err();
        match err {
            OptunaError::InvalidParam(msg) => {
                assert!(msg.contains("unknown sampler 'genetic'"), "{msg}");
                assert!(msg.contains("tpe"), "must enumerate registered names: {msg}");
            }
            other => panic!("expected InvalidParam, got {other:?}"),
        }
        // spec + explicit instance is a contradiction, not a silent pick
        let err = Study::builder()
            .name("spec-both")
            .sampler(Arc::new(RandomSampler::new(0)))
            .sampler_spec("random")
            .build()
            .unwrap_err();
        assert!(matches!(err, OptunaError::InvalidParam(_)), "{err:?}");
    }

    #[test]
    fn best_trials_applies_deb_dominance_when_constraints_reported() {
        let study = Study::builder()
            .name("constrained-front")
            .directions(&[StudyDirection::Minimize, StudyDirection::Minimize])
            .sampler(Arc::new(RandomSampler::new(11)))
            .build()
            .unwrap();
        // four hand-placed points: the two infeasible ones Pareto-dominate
        // everything, but Deb's rules must keep them off the front
        let place = |xy: (f64, f64), violation: f64| {
            let mut t = study.ask().unwrap();
            t.suggest_float("x", 0.0, 1.0).unwrap();
            t.report_constraints(&[violation]).unwrap();
            study.tell(t, TrialOutcome::CompleteValues(vec![xy.0, xy.1])).unwrap();
        };
        place((0.0, 0.0), 1.0); // infeasible, dominates all
        place((0.1, 0.1), 0.5); // infeasible
        place((0.5, 1.0), -1.0); // feasible, front
        place((1.0, 0.5), 0.0); // feasible (boundary), front
        let front = study.best_trials().unwrap();
        let numbers: Vec<u64> = front.iter().map(|t| t.number).collect();
        assert_eq!(numbers, vec![2, 3], "front must be the feasible points");
        assert!(front.iter().all(|t| t.is_feasible()));
    }
}
