//! `Study` — one optimization process (§2): owns storage, sampler and
//! pruner, runs the optimize loop, and exposes ask/tell for custom loops.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::core::{
    FrozenTrial, IndexSnapshot, ObservationIndex, OptunaError, StudyDirection, TrialState,
};
use crate::pruner::{NopPruner, Pruner};
use crate::sampler::{Sampler, StudyContext, TpeSampler};
use crate::storage::{get_or_create_study, CachedStorage, InMemoryStorage, Storage, SEQ_UNTRACKED};
use crate::trial::Trial;

/// A study: the unit of optimization. Cheap to share across threads by
/// reference (`optimize_parallel` uses scoped threads).
pub struct Study {
    pub(crate) storage: Arc<dyn Storage>,
    pub(crate) sampler: Arc<dyn Sampler>,
    pub(crate) pruner: Arc<dyn Pruner>,
    /// Generation-stamped observation index over this study's trials
    /// (`None` when disabled via [`StudyBuilder::observation_index`]).
    pub(crate) obs_index: Option<Mutex<ObservationIndex>>,
    pub study_id: u64,
    pub direction: StudyDirection,
    pub name: String,
}

/// Fluent construction (`Study::builder().sampler(...).build()?`).
pub struct StudyBuilder {
    name: String,
    direction: StudyDirection,
    storage: Option<Arc<dyn Storage>>,
    sampler: Option<Arc<dyn Sampler>>,
    pruner: Option<Arc<dyn Pruner>>,
    cache: bool,
    index: bool,
}

impl StudyBuilder {
    pub fn name(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn direction(mut self, direction: StudyDirection) -> Self {
        self.direction = direction;
        self
    }

    pub fn storage(mut self, storage: Arc<dyn Storage>) -> Self {
        self.storage = Some(storage);
        self
    }

    pub fn sampler(mut self, sampler: Arc<dyn Sampler>) -> Self {
        self.sampler = Some(sampler);
        self
    }

    pub fn pruner(mut self, pruner: Arc<dyn Pruner>) -> Self {
        self.pruner = Some(pruner);
        self
    }

    /// Enable/disable the write-through snapshot cache around the storage
    /// backend (see [`CachedStorage`]). On by default; turning it off
    /// restores the one-full-clone-per-read behaviour — useful for
    /// benchmarking the raw path (`benches/perf_micro.rs` does).
    pub fn storage_caching(mut self, enabled: bool) -> Self {
        self.cache = enabled;
        self
    }

    /// Enable/disable the generation-stamped observation index (see
    /// [`crate::core::ObservationIndex`]). On by default; turning it off
    /// restores the scan-per-call sampler/pruner hot paths — useful for
    /// benchmarking and for the equivalence suite
    /// (rust/tests/obs_index_equiv.rs), which proves the two paths make
    /// identical decisions.
    pub fn observation_index(mut self, enabled: bool) -> Self {
        self.index = enabled;
        self
    }

    /// Create (or join, for shared storage) the study.
    pub fn build(self) -> Result<Study, OptunaError> {
        let storage = self
            .storage
            .unwrap_or_else(|| Arc::new(InMemoryStorage::new()));
        let storage = if self.cache { CachedStorage::wrap(storage) } else { storage };
        let sampler = self.sampler.unwrap_or_else(|| Arc::new(TpeSampler::new(0)));
        let pruner = self.pruner.unwrap_or_else(|| Arc::new(NopPruner));
        let study_id = get_or_create_study(storage.as_ref(), &self.name, self.direction)?;
        let obs_index = self
            .index
            .then(|| Mutex::new(ObservationIndex::new(self.direction)));
        Ok(Study {
            storage,
            sampler,
            pruner,
            obs_index,
            study_id,
            direction: self.direction,
            name: self.name,
        })
    }
}

/// Result an objective hands back through [`Study::tell`].
pub enum TrialOutcome {
    Complete(f64),
    Pruned,
    Failed(String),
}

impl Study {
    pub fn builder() -> StudyBuilder {
        StudyBuilder {
            name: "study".to_string(),
            direction: StudyDirection::Minimize,
            storage: None,
            sampler: None,
            pruner: None,
            cache: true,
            index: true,
        }
    }

    /// Advance the observation index to the storage's current sequence
    /// number and return its snapshot (`None` when the index is
    /// disabled). O(1) on a quiet study — a sequence-number compare —
    /// and O(changed trials) otherwise, via the same delta stream the
    /// snapshot cache uses.
    pub(crate) fn sync_obs_index(&self) -> Result<Option<Arc<IndexSnapshot>>, OptunaError> {
        let Some(index) = &self.obs_index else {
            return Ok(None);
        };
        let mut ix = index.lock().unwrap();
        let seq = self.storage.study_seq(self.study_id)?;
        if seq != SEQ_UNTRACKED && seq == ix.seq() {
            return Ok(Some(ix.snapshot()));
        }
        let delta = self.storage.get_trials_since(self.study_id, ix.seq())?;
        Ok(Some(ix.apply(&delta.trials, delta.seq)))
    }

    /// Begin a trial: creates it in storage and runs relational sampling.
    /// The history snapshot taken here is shared by every independent
    /// suggest in the trial, and — through the storage cache — with every
    /// concurrent worker: unless the study changed since the last read,
    /// no trial data is cloned at all. The observation index is synced to
    /// the same generation, so every suggest in the trial reads pre-sorted
    /// observation columns instead of scanning the snapshot.
    pub fn ask(&self) -> Result<Trial<'_>, OptunaError> {
        let (trial_id, number) = self.storage.create_trial(self.study_id)?;
        let trials = self.storage.get_trials_snapshot(self.study_id)?;
        let index = self.sync_obs_index()?;
        let ctx = StudyContext::with_index(self.direction, &trials, index.as_deref());
        let space = self.sampler.infer_relative_search_space(&ctx);
        let relative = if space.is_empty() {
            Default::default()
        } else {
            self.sampler.sample_relative(&ctx, number, &space)
        };
        Ok(Trial::new(self, trial_id, number, relative, space, trials, index))
    }

    /// Finish a trial with an outcome.
    pub fn tell(&self, trial: Trial<'_>, outcome: TrialOutcome) -> Result<(), OptunaError> {
        match outcome {
            TrialOutcome::Complete(v) => {
                self.storage.finish_trial(trial.trial_id, TrialState::Complete, Some(v))
            }
            TrialOutcome::Pruned => {
                let v = trial.last_report.map(|(_, v)| v);
                self.storage.finish_trial(trial.trial_id, TrialState::Pruned, v)
            }
            TrialOutcome::Failed(msg) => {
                self.storage
                    .set_trial_user_attr(trial.trial_id, "fail_reason", &msg)
                    .ok();
                self.storage.finish_trial(trial.trial_id, TrialState::Failed, None)
            }
        }
    }

    /// Run one trial through `objective` (the optimize-loop body).
    pub fn run_one<F>(&self, objective: &F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError>,
    {
        let mut trial = self.ask()?;
        let outcome = match objective(&mut trial) {
            Ok(v) if v.is_finite() => TrialOutcome::Complete(v),
            Ok(v) => TrialOutcome::Failed(format!("non-finite objective value {v}")),
            Err(OptunaError::TrialPruned) => TrialOutcome::Pruned,
            Err(e) => TrialOutcome::Failed(e.to_string()),
        };
        self.tell(trial, outcome)
    }

    /// Evaluate `objective` for `n_trials` trials (the 'optimize API').
    /// Pruned and failed trials are recorded, not fatal.
    ///
    /// ```
    /// use optuna_rs::prelude::*;
    ///
    /// let study = Study::builder().name("doc-optimize").build().unwrap();
    /// study.optimize(20, |trial| {
    ///     let x = trial.suggest_float("x", -10.0, 10.0)?;
    ///     Ok((x - 2.0).powi(2))
    /// }).unwrap();
    /// assert_eq!(study.trials().unwrap().len(), 20);
    /// assert!(study.best_value().unwrap().is_some());
    /// ```
    pub fn optimize<F>(&self, n_trials: usize, objective: F) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError>,
    {
        for _ in 0..n_trials {
            self.run_one(&objective)?;
        }
        Ok(())
    }

    /// Parallel optimization with `n_workers` threads sharing this study's
    /// storage — the paper's Fig 7/11b architecture in-process. The total
    /// across workers is `n_trials`. Workers coordinate only through
    /// storage; the snapshot cache hands all of them the same `Arc`'d
    /// trial history per generation — the history is copied at most once
    /// per storage generation (when a delta lands while workers still
    /// hold the previous snapshot), not once per reader as on the
    /// uncached path.
    ///
    /// ```
    /// use optuna_rs::prelude::*;
    ///
    /// let study = Study::builder().name("doc-parallel").build().unwrap();
    /// study.optimize_parallel(16, 4, |trial| {
    ///     let x = trial.suggest_float("x", 0.0, 1.0)?;
    ///     Ok(x * x)
    /// }).unwrap();
    /// assert_eq!(study.trials().unwrap().len(), 16);
    /// ```
    pub fn optimize_parallel<F>(
        &self,
        n_trials: usize,
        n_workers: usize,
        objective: F,
    ) -> Result<(), OptunaError>
    where
        F: Fn(&mut Trial<'_>) -> Result<f64, OptunaError> + Sync,
        Self: Sync,
    {
        assert!(n_workers >= 1);
        let budget = AtomicUsize::new(n_trials);
        let first_error = std::sync::Mutex::new(None::<OptunaError>);
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                scope.spawn(|| loop {
                    // claim a trial slot
                    let prev = budget.fetch_update(
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                        |b| b.checked_sub(1),
                    );
                    if prev.is_err() {
                        break;
                    }
                    if let Err(e) = self.run_one(&objective) {
                        *first_error.lock().unwrap() = Some(e);
                        break;
                    }
                });
            }
        });
        match first_error.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// All trials, ordered by number.
    pub fn trials(&self) -> Result<Vec<FrozenTrial>, OptunaError> {
        self.storage.get_all_trials(self.study_id)
    }

    /// Best completed trial under the study direction. Scans the shared
    /// snapshot and clones only the winner.
    pub fn best_trial(&self) -> Result<Option<FrozenTrial>, OptunaError> {
        let trials = self.storage.get_trials_snapshot(self.study_id)?;
        Ok(trials
            .iter()
            .filter(|t| t.state == TrialState::Complete && t.value.is_some())
            .reduce(|best, t| {
                if self.direction.is_better(t.value.unwrap(), best.value.unwrap()) {
                    t
                } else {
                    best
                }
            })
            .cloned())
    }

    /// Best objective value, if any trial completed.
    pub fn best_value(&self) -> Result<Option<f64>, OptunaError> {
        Ok(self.best_trial()?.and_then(|t| t.value))
    }

    /// Export the trial table as CSV (the pandas-dataframe analog, §4).
    pub fn to_csv(&self) -> Result<String, OptunaError> {
        let trials = self.trials()?;
        // union of parameter names, ordered
        let mut names: Vec<String> = Vec::new();
        for t in &trials {
            for k in t.params.keys() {
                if !names.contains(k) {
                    names.push(k.clone());
                }
            }
        }
        names.sort();
        let mut out = String::from("number,state,value");
        for n in &names {
            out.push(',');
            out.push_str(n);
        }
        out.push('\n');
        for t in &trials {
            out.push_str(&format!(
                "{},{},{}",
                t.number,
                t.state.as_str(),
                t.value.map(|v| v.to_string()).unwrap_or_default()
            ));
            for n in &names {
                out.push(',');
                if let Some(v) = t.param(n) {
                    out.push_str(&v.to_string());
                }
            }
            out.push('\n');
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ParamValue;
    use crate::pruner::AshaPruner;
    use crate::sampler::RandomSampler;
    use crate::trial::TrialApi;

    fn quadratic_study(seed: u64) -> Study {
        Study::builder()
            .name("quad")
            .sampler(Arc::new(RandomSampler::new(seed)))
            .build()
            .unwrap()
    }

    #[test]
    fn optimize_records_trials_and_best() {
        let study = quadratic_study(0);
        study
            .optimize(50, |t| {
                let x = t.suggest_float("x", -5.0, 5.0)?;
                Ok(x * x)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 50);
        assert!(trials.iter().all(|t| t.state == TrialState::Complete));
        let best = study.best_trial().unwrap().unwrap();
        assert!(best.value.unwrap() < 1.0, "best={:?}", best.value);
        match best.param("x").unwrap() {
            ParamValue::Float(x) => {
                assert!((x * x - best.value.unwrap()).abs() < 1e-9)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn dynamic_conditional_space() {
        // Fig 3 analog: branch on a categorical; params exist per-branch.
        let study = quadratic_study(1);
        study
            .optimize(40, |t| {
                let kind = t.suggest_categorical("model", &["linear", "mlp"])?;
                if kind == "mlp" {
                    let n_layers = t.suggest_int("n_layers", 1, 3)?;
                    let mut total = 0.0;
                    for i in 0..n_layers {
                        total += t.suggest_int(&format!("units_l{i}"), 4, 64)? as f64;
                    }
                    Ok(total / 64.0)
                } else {
                    let reg = t.suggest_float_log("reg", 1e-5, 1.0)?;
                    Ok(reg.ln().abs() / 10.0)
                }
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 40);
        let mlps = trials
            .iter()
            .filter(|t| t.param("model") == Some(ParamValue::Cat("mlp".into())))
            .count();
        assert!(mlps > 5 && mlps < 35, "mlps={mlps}");
        // branch params only exist where taken
        for t in &trials {
            let is_mlp = t.param("model") == Some(ParamValue::Cat("mlp".into()));
            assert_eq!(t.params.contains_key("n_layers"), is_mlp);
            assert_eq!(t.params.contains_key("reg"), !is_mlp);
        }
    }

    #[test]
    fn resuggest_same_name_is_idempotent() {
        let study = quadratic_study(2);
        study
            .optimize(3, |t| {
                let a = t.suggest_float("x", 0.0, 1.0)?;
                let b = t.suggest_float("x", 0.0, 1.0)?;
                assert_eq!(a, b);
                // changing the distribution mid-trial is an error
                assert!(t.suggest_float("x", 0.0, 2.0).is_err());
                Ok(a)
            })
            .unwrap();
    }

    #[test]
    fn failed_trials_recorded_not_fatal() {
        let study = quadratic_study(3);
        study
            .optimize(10, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                if x < 0.5 {
                    Err(OptunaError::Objective("boom".into()))
                } else {
                    Ok(x)
                }
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 10);
        let failed = trials.iter().filter(|t| t.state == TrialState::Failed).count();
        assert!(failed > 0);
        assert!(trials
            .iter()
            .filter(|t| t.state == TrialState::Failed)
            .all(|t| t.user_attrs.contains_key("fail_reason")));
    }

    #[test]
    fn non_finite_objective_fails_trial() {
        let study = quadratic_study(4);
        study.optimize(2, |_t| Ok(f64::NAN)).unwrap();
        assert!(study
            .trials()
            .unwrap()
            .iter()
            .all(|t| t.state == TrialState::Failed));
    }

    #[test]
    fn pruning_loop_fig5() {
        // Fig 5 pattern: report + should_prune inside iterative training.
        let study = Study::builder()
            .name("pruned")
            .sampler(Arc::new(RandomSampler::new(5)))
            .pruner(Arc::new(AshaPruner::new()))
            .build()
            .unwrap();
        study
            .optimize(60, |t| {
                let lr = t.suggest_float("lr", 0.0, 1.0)?;
                // simple synthetic curve: bad lr ⇒ high plateau
                let mut v = 1.0;
                for step in 1..=16u64 {
                    v = (lr - 0.3).abs() + 1.0 / step as f64;
                    t.report(step, v)?;
                    if t.should_prune()? {
                        return Err(OptunaError::TrialPruned);
                    }
                }
                Ok(v)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        let pruned = trials.iter().filter(|t| t.state == TrialState::Pruned).count();
        let complete = trials.iter().filter(|t| t.state == TrialState::Complete).count();
        assert!(pruned > 10, "pruned={pruned}");
        assert!(complete > 0);
        // pruned trials carry their last intermediate as value
        assert!(trials
            .iter()
            .filter(|t| t.state == TrialState::Pruned)
            .all(|t| t.value.is_some()));
    }

    #[test]
    fn parallel_optimize_shares_history() {
        let study = quadratic_study(6);
        study
            .optimize_parallel(64, 8, |t| {
                let x = t.suggest_float("x", -5.0, 5.0)?;
                Ok(x * x)
            })
            .unwrap();
        let trials = study.trials().unwrap();
        assert_eq!(trials.len(), 64);
        let mut numbers: Vec<u64> = trials.iter().map(|t| t.number).collect();
        numbers.sort_unstable();
        assert_eq!(numbers, (0..64).collect::<Vec<u64>>());
    }

    #[test]
    fn cached_and_uncached_storage_agree() {
        // same seed, caching on vs off: identical trajectories
        let run = |cached: bool| -> Vec<Option<f64>> {
            let study = Study::builder()
                .name("cache-eq")
                .sampler(Arc::new(RandomSampler::new(11)))
                .storage_caching(cached)
                .build()
                .unwrap();
            study
                .optimize(25, |t| {
                    let x = t.suggest_float("x", -1.0, 1.0)?;
                    t.report(1, x)?;
                    Ok(x)
                })
                .unwrap();
            study.trials().unwrap().into_iter().map(|t| t.value).collect()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn builder_wraps_storage_in_cache_by_default() {
        let study = quadratic_study(12);
        assert!(study.storage.is_write_through_cache());
        let raw = Study::builder()
            .name("raw")
            .storage_caching(false)
            .build()
            .unwrap();
        assert!(!raw.storage.is_write_through_cache());
    }

    #[test]
    fn builder_observation_index_default_on_and_optional() {
        let study = quadratic_study(13);
        assert!(study.obs_index.is_some());
        let plain = Study::builder()
            .name("no-index")
            .observation_index(false)
            .build()
            .unwrap();
        assert!(plain.obs_index.is_none());
        assert!(plain.sync_obs_index().unwrap().is_none());
    }

    #[test]
    fn obs_index_tracks_study_through_optimize() {
        let study = Study::builder()
            .name("idx-sync")
            .sampler(Arc::new(RandomSampler::new(14)))
            .build()
            .unwrap();
        study
            .optimize(12, |t| {
                let x = t.suggest_float("x", -1.0, 1.0)?;
                t.report(1, x)?;
                Ok(x)
            })
            .unwrap();
        let snap = study.sync_obs_index().unwrap().unwrap();
        assert_eq!(snap.n_finished(), 12);
        let d = crate::core::Distribution::float(-1.0, 1.0);
        let col = snap.param_column("x", &d).unwrap();
        assert_eq!(col.len(), 12);
        // losses come out ascending
        for w in col.values_by_loss().windows(2) {
            assert!(w[0] <= w[1], "losses (=values here) must ascend");
        }
        assert_eq!(snap.step_column(1).unwrap().len(), 12);
        // quiet study: repeated syncs share the same snapshot Arc
        let again = study.sync_obs_index().unwrap().unwrap();
        assert!(Arc::ptr_eq(&snap, &again));
    }

    #[test]
    fn ask_tell_api() {
        let study = quadratic_study(7);
        let mut t = study.ask().unwrap();
        let x = t.suggest_float("x", 0.0, 1.0).unwrap();
        study.tell(t, TrialOutcome::Complete(x)).unwrap();
        let t2 = study.ask().unwrap();
        assert_eq!(t2.number(), 1);
        study.tell(t2, TrialOutcome::Failed("skip".into())).unwrap();
        assert_eq!(study.trials().unwrap().len(), 2);
        assert_eq!(study.best_value().unwrap(), Some(x));
    }

    #[test]
    fn csv_export_contains_params() {
        let study = quadratic_study(8);
        study
            .optimize(5, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                let c = t.suggest_categorical("c", &["a", "b"])?;
                Ok(x + if c == "a" { 0.0 } else { 1.0 })
            })
            .unwrap();
        let csv = study.to_csv().unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 6);
        assert!(lines[0].starts_with("number,state,value"));
        assert!(lines[0].contains(",c") && lines[0].contains(",x"));
    }

    #[test]
    fn maximize_direction_best() {
        let study = Study::builder()
            .name("max")
            .direction(StudyDirection::Maximize)
            .sampler(Arc::new(RandomSampler::new(9)))
            .build()
            .unwrap();
        study
            .optimize(30, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(x)
            })
            .unwrap();
        assert!(study.best_value().unwrap().unwrap() > 0.8);
    }
}
