//! Static dashboard (Fig 8 analog): renders a study to a self-contained
//! HTML page with inline SVG — optimization history, parallel
//! coordinates, intermediate-value curves, and the trials table.
//! No server required; `optuna dashboard --out report.html` writes it.

use crate::core::{OptunaError, TrialState};
use crate::study::Study;
use std::fmt::Write as _;

/// Map a value range to SVG y (flipped). Degenerate inputs clamp to the
/// mid-band instead of leaking `NaN` coordinates into the SVG: a
/// one-trial (or all-equal) history collapses the range to `lo == hi`,
/// and a NaN objective value survives into the trial table — both used
/// to normalize to `NaN/0` here and render an invisible plot.
fn y_of(v: f64, lo: f64, hi: f64, height: f64) -> f64 {
    if !v.is_finite() || !(hi > lo) || !(hi - lo).is_finite() {
        return height / 2.0;
    }
    height - (v - lo) / (hi - lo) * height
}

/// SVG polyline from points.
fn polyline(points: &[(f64, f64)], stroke: &str) -> String {
    let pts: Vec<String> = points.iter().map(|(x, y)| format!("{x:.1},{y:.1}")).collect();
    format!(
        "<polyline fill='none' stroke='{stroke}' stroke-width='1.5' points='{}'/>",
        pts.join(" ")
    )
}

/// Human-readable wall-clock duration from epoch-ms start/complete stamps
/// (empty when either stamp is missing, e.g. pre-timestamp journals).
fn fmt_duration(start: Option<u64>, complete: Option<u64>) -> String {
    match (start, complete) {
        (Some(s), Some(c)) if c >= s => {
            let ms = c - s;
            if ms < 1000 {
                format!("{ms}ms")
            } else {
                format!("{:.1}s", ms as f64 / 1000.0)
            }
        }
        _ => String::new(),
    }
}

/// Seconds at human scale for the telemetry tables (`12.3us`, `4.56ms`).
fn fmt_seconds(v: f64) -> String {
    if v < 1e-3 {
        format!("{:.1}us", v * 1e6)
    } else if v < 1.0 {
        format!("{:.2}ms", v * 1e3)
    } else {
        format!("{v:.3}s")
    }
}

/// The trial's objective cell: the scalar value, or all values of a
/// multi-objective trial joined with `;`.
fn fmt_values(t: &crate::core::FrozenTrial) -> String {
    let values = t.objective_values();
    match values.len() {
        0 => String::new(),
        1 => format!("{:.6}", values[0]),
        _ => values
            .iter()
            .map(|v| format!("{v:.6}"))
            .collect::<Vec<_>>()
            .join("; "),
    }
}

/// Render the study report.
pub fn render_html(study: &Study) -> Result<String, OptunaError> {
    let trials = study.trials()?;
    let finished: Vec<_> = trials
        .iter()
        .filter(|t| t.state == TrialState::Complete || t.state == TrialState::Pruned)
        .collect();
    let values: Vec<(u64, f64, TrialState)> = finished
        .iter()
        .filter_map(|t| t.value.map(|v| (t.number, v, t.state)))
        .collect();

    let mut html = String::new();
    let _ = write!(
        html,
        "<!doctype html><html><head><meta charset='utf-8'>\
         <title>optuna-rs: {name}</title>\
         <style>body{{font-family:sans-serif;margin:2em}}table{{border-collapse:collapse}}\
         td,th{{border:1px solid #ccc;padding:2px 8px;font-size:12px}}\
         .pruned{{color:#b65}}.complete{{color:#276}}h2{{margin-top:1.5em}}</style>\
         </head><body><h1>Study: {name} ({dir})</h1>",
        name = study.name,
        dir = study
            .directions
            .iter()
            .map(|d| d.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );

    // ---- optimization history ------------------------------------------
    let (w, h) = (640.0, 240.0);
    if !values.is_empty() {
        let lo = values.iter().map(|v| v.1).fold(f64::INFINITY, f64::min);
        let hi = values.iter().map(|v| v.1).fold(f64::NEG_INFINITY, f64::max);
        let n = values.iter().map(|v| v.0).max().unwrap().max(1) as f64;
        let mut dots = String::new();
        let mut best_pts = Vec::new();
        let mut best = f64::NAN;
        for (num, v, state) in &values {
            let x = *num as f64 / n * w;
            let y = y_of(*v, lo, hi, h);
            let color = if *state == TrialState::Pruned { "#cc8855" } else { "#227766" };
            let _ = write!(dots, "<circle cx='{x:.1}' cy='{y:.1}' r='2.2' fill='{color}'/>");
            if *state == TrialState::Complete {
                if best.is_nan() || study.direction.is_better(*v, best) {
                    best = *v;
                }
                best_pts.push((x, y_of(best, lo, hi, h)));
            }
        }
        let _ = write!(
            html,
            "<h2>Optimization history</h2>\
             <svg width='{w}' height='{h}' style='background:#fafafa;border:1px solid #ddd'>\
             {dots}{line}</svg>\
             <div>range [{lo:.6} … {hi:.6}]; best line in blue</div>",
            line = polyline(&best_pts, "#3355cc"),
        );
    }

    // ---- parallel coordinates -------------------------------------------
    let mut names: Vec<String> = Vec::new();
    for t in &finished {
        for k in t.params.keys() {
            if !names.contains(k) {
                names.push(k.clone());
            }
        }
    }
    names.sort();
    if !names.is_empty() && !values.is_empty() {
        let lo = values.iter().map(|v| v.1).fold(f64::INFINITY, f64::min);
        let hi = values.iter().map(|v| v.1).fold(f64::NEG_INFINITY, f64::max);
        let mut lines = String::new();
        let cols = names.len().max(2);
        for t in &finished {
            let Some(v) = t.value else { continue };
            // color by objective rank (greener = better)
            let frac = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
            let frac = match study.direction {
                crate::core::StudyDirection::Minimize => frac,
                crate::core::StudyDirection::Maximize => 1.0 - frac,
            };
            let red = (64.0 + 180.0 * frac) as u32;
            let green = (190.0 - 140.0 * frac) as u32;
            let mut pts = Vec::new();
            for (ci, name) in names.iter().enumerate() {
                if let Some((dist, internal)) = t.params.get(name) {
                    let (dlo, dhi) = dist.internal_range();
                    let fy = if dhi > dlo { (internal - dlo) / (dhi - dlo) } else { 0.5 };
                    let x = ci as f64 / (cols - 1) as f64 * w;
                    pts.push((x, h - fy * h));
                }
            }
            if pts.len() >= 2 {
                let _ = write!(
                    lines,
                    "{}",
                    polyline(&pts, &format!("rgba({red},{green},110,0.45)"))
                );
            }
        }
        let axis_labels: Vec<String> = names
            .iter()
            .enumerate()
            .map(|(ci, name)| {
                let x = ci as f64 / (cols - 1) as f64 * w;
                format!(
                    "<line x1='{x:.0}' y1='0' x2='{x:.0}' y2='{h}' stroke='#bbb'/>\
                     <text x='{x:.0}' y='{ty}' font-size='10'>{name}</text>",
                    ty = h + 12.0
                )
            })
            .collect();
        let _ = write!(
            html,
            "<h2>Parallel coordinates</h2>\
             <svg width='{w}' height='{hh}' style='background:#fafafa;border:1px solid #ddd'>\
             {axes}{lines}</svg>",
            hh = h + 18.0,
            axes = axis_labels.join("")
        );
    }

    // ---- intermediate values (learning curves) ---------------------------
    let curves: Vec<_> = finished.iter().filter(|t| !t.intermediate.is_empty()).collect();
    if !curves.is_empty() {
        let max_step = curves
            .iter()
            .flat_map(|t| t.intermediate.keys())
            .max()
            .copied()
            .unwrap_or(1) as f64;
        let vlo = curves
            .iter()
            .flat_map(|t| t.intermediate.values())
            .fold(f64::INFINITY, |a, &b| a.min(b));
        let vhi = curves
            .iter()
            .flat_map(|t| t.intermediate.values())
            .fold(f64::NEG_INFINITY, |a, &b| a.max(b));
        let mut lines = String::new();
        for t in curves.iter().take(200) {
            let pts: Vec<(f64, f64)> = t
                .intermediate
                .iter()
                .map(|(s, v)| (*s as f64 / max_step * w, y_of(*v, vlo, vhi, h)))
                .collect();
            let color = if t.state == TrialState::Pruned {
                "rgba(204,136,85,0.5)"
            } else {
                "rgba(34,119,102,0.7)"
            };
            let _ = write!(lines, "{}", polyline(&pts, color));
        }
        let _ = write!(
            html,
            "<h2>Intermediate values</h2>\
             <svg width='{w}' height='{h}' style='background:#fafafa;border:1px solid #ddd'>{lines}</svg>\
             <div>orange = pruned, green = completed (first 200 trials)</div>"
        );
    }

    // ---- Pareto front (multi-objective studies) --------------------------
    if study.is_multi_objective() {
        let front = study.best_trials()?;
        let front_numbers: std::collections::HashSet<u64> =
            front.iter().map(|t| t.number).collect();
        let _ = write!(
            html,
            "<h2>Pareto front ({} of {} completed trials)</h2>",
            front.len(),
            trials.iter().filter(|t| t.state == TrialState::Complete).count()
        );
        // objective-space scatter for the 2-objective case: dominated
        // trials in grey, the front highlighted
        if study.n_objectives() == 2 {
            let pts: Vec<(u64, f64, f64)> = trials
                .iter()
                .filter(|t| t.state == TrialState::Complete)
                .filter_map(|t| {
                    let v = t.objective_values();
                    // non-finite values would render as cx='NaN' — skip
                    (v.len() == 2 && v.iter().all(|x| x.is_finite()))
                        .then(|| (t.number, v[0], v[1]))
                })
                .collect();
            if !pts.is_empty() {
                let (xlo, xhi) = pts
                    .iter()
                    .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), p| {
                        (l.min(p.1), h.max(p.1))
                    });
                let (ylo, yhi) = pts
                    .iter()
                    .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), p| {
                        (l.min(p.2), h.max(p.2))
                    });
                let mut dots = String::new();
                for (num, v0, v1) in &pts {
                    let x = if xhi > xlo { (v0 - xlo) / (xhi - xlo) * w } else { w / 2.0 };
                    let y = y_of(*v1, ylo, yhi, h);
                    let (color, r) = if front_numbers.contains(num) {
                        ("#3355cc", 3.0)
                    } else {
                        ("#bbbbbb", 2.0)
                    };
                    let _ = write!(
                        dots,
                        "<circle cx='{x:.1}' cy='{y:.1}' r='{r}' fill='{color}'/>"
                    );
                }
                let _ = write!(
                    html,
                    "<svg width='{w}' height='{h}' style='background:#fafafa;\
                     border:1px solid #ddd'>{dots}</svg>\
                     <div>objective 0 → / objective 1 ↑; front in blue</div>"
                );
            }
        }
        let _ = write!(html, "<table><tr><th>#</th><th>values</th></tr>");
        for t in front.iter().take(200) {
            let _ = write!(
                html,
                "<tr><td>{}</td><td>{}</td></tr>",
                t.number,
                fmt_values(t)
            );
        }
        html.push_str("</table>");
    }

    // ---- telemetry --------------------------------------------------------
    if let Some(tel) = study.telemetry() {
        study.fold_resilience_stats();
        let snap = tel.registry().snapshot();
        // per-op error totals, keyed by op name
        let mut op_errors: std::collections::BTreeMap<&str, u64> = Default::default();
        for ((name, labels), v) in &snap.counters {
            if name == "optuna_storage_op_errors_total" {
                if let Some((_, op)) = labels.iter().find(|(k, _)| k == "op") {
                    *op_errors.entry(op.as_str()).or_insert(0) += v;
                }
            }
        }
        let mut ops = String::new();
        let mut spans = String::new();
        for ((name, labels), hist) in &snap.histograms {
            let label =
                |key: &str| labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str());
            if name == "optuna_storage_op_duration_seconds" {
                let Some(op) = label("op") else { continue };
                let errors = op_errors.get(op).copied().unwrap_or(0);
                if hist.count == 0 && errors == 0 {
                    continue; // untouched op: no row
                }
                let _ = write!(
                    ops,
                    "<tr><td>{op}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
                     <td>{errors}</td></tr>",
                    hist.count,
                    fmt_seconds(hist.p50),
                    fmt_seconds(hist.p95),
                    fmt_seconds(hist.p99)
                );
            } else if name == "optuna_span_duration_seconds" {
                let Some(span) = label("span") else { continue };
                if hist.count == 0 {
                    continue;
                }
                let _ = write!(
                    spans,
                    "<tr><td>{span}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td></tr>",
                    hist.count,
                    fmt_seconds(hist.p50),
                    fmt_seconds(hist.p95),
                    fmt_seconds(hist.p99)
                );
            }
        }
        if !ops.is_empty() {
            let _ = write!(
                html,
                "<h2>Telemetry: storage ops</h2><table><tr><th>op</th><th>count</th>\
                 <th>p50</th><th>p95</th><th>p99</th><th>errors</th></tr>{ops}</table>"
            );
        }
        if !spans.is_empty() {
            let _ = write!(
                html,
                "<h2>Telemetry: spans</h2><table><tr><th>span</th><th>count</th>\
                 <th>p50</th><th>p95</th><th>p99</th></tr>{spans}</table>"
            );
        }
    }

    // ---- resilience -------------------------------------------------------
    // rendered whenever a retry layer is attached, telemetry or not
    if let Some(stats) = study.resilience_stats() {
        let _ = write!(
            html,
            "<h2>Resilience</h2><table>\
             <tr><th>retries</th><th>recovered</th><th>exhausted</th>\
             <th>degraded heartbeats</th><th>degraded compactions</th>\
             <th>stale reads</th><th>absorbed ambiguous</th></tr>\
             <tr><td>{}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
             <td>{}</td><td>{}</td></tr></table>",
            stats.retries,
            stats.recovered,
            stats.exhausted,
            stats.dropped_heartbeats,
            stats.dropped_compactions,
            stats.stale_reads,
            stats.absorbed_ambiguous
        );
    }

    // ---- trials table -----------------------------------------------------
    let _ = write!(
        html,
        "<h2>Trials ({} total)</h2><table><tr><th>#</th><th>state</th><th>value</th>\
         <th>start</th><th>end</th><th>duration</th><th>retries</th>{}</tr>",
        trials.len(),
        names.iter().map(|n| format!("<th>{n}</th>")).collect::<String>()
    );
    for t in trials.iter().take(500) {
        let _ = write!(
            html,
            "<tr class='{cls}'><td>{num}</td><td>{state}</td><td>{val}</td>\
             <td>{start}</td><td>{end}</td><td>{dur}</td><td>{retries}</td>{cells}</tr>",
            cls = t.state.as_str(),
            num = t.number,
            state = t.state.as_str(),
            val = fmt_values(t),
            start = t.datetime_start.map(|m| m.to_string()).unwrap_or_default(),
            end = t.datetime_complete.map(|m| m.to_string()).unwrap_or_default(),
            dur = fmt_duration(t.datetime_start, t.datetime_complete),
            retries = t.retry_count(),
            cells = names
                .iter()
                .map(|n| format!(
                    "<td>{}</td>",
                    t.param(n).map(|p| p.to_string()).unwrap_or_default()
                ))
                .collect::<String>()
        );
    }
    html.push_str("</table></body></html>");
    Ok(html)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prelude::*;
    use std::sync::Arc;

    fn demo_study() -> Study {
        let study = Study::builder()
            .name("dash-demo")
            .sampler(Arc::new(RandomSampler::new(0)))
            .pruner(Arc::new(AshaPruner::new()))
            .build()
            .unwrap();
        study
            .optimize(25, |t| {
                let x = t.suggest_float("x", -2.0, 2.0)?;
                let c = t.suggest_categorical("kind", &["a", "b"])?;
                for step in 1..=8 {
                    t.report(step, x * x + 1.0 / step as f64)?;
                    if t.should_prune()? {
                        return Err(OptunaError::TrialPruned);
                    }
                }
                Ok(x * x + if c == "a" { 0.0 } else { 0.1 })
            })
            .unwrap();
        study
    }

    #[test]
    fn renders_all_sections() {
        let study = demo_study();
        let html = render_html(&study).unwrap();
        assert!(html.contains("Optimization history"));
        assert!(html.contains("Parallel coordinates"));
        assert!(html.contains("Intermediate values"));
        assert!(html.contains("Trials ("));
        assert!(html.contains("<svg"));
        assert!(html.contains("dash-demo"));
        // well-formed-ish: tags balance for the big ones
        assert_eq!(html.matches("<table>").count(), html.matches("</table>").count());
        assert_eq!(html.matches("<svg").count(), html.matches("</svg>").count());
    }

    #[test]
    fn empty_study_renders() {
        let study = Study::builder().name("empty").build().unwrap();
        let html = render_html(&study).unwrap();
        assert!(html.contains("Trials (0 total)"));
    }

    #[test]
    fn one_complete_trial_renders_without_nan() {
        // regression: a single trial makes lo == hi in the optimization
        // history, which used to normalize to NaN/0 and emit NaN
        // coordinates into the SVG
        let study = Study::builder()
            .name("dash-one")
            .sampler(Arc::new(RandomSampler::new(5)))
            .build()
            .unwrap();
        study.optimize(1, |t| t.suggest_float("x", 0.0, 1.0).map(|_| 3.5)).unwrap();
        let html = render_html(&study).unwrap();
        assert!(html.contains("Optimization history"));
        assert!(!html.contains("NaN"), "degenerate range leaked NaN: {html}");
    }

    #[test]
    fn nan_objective_value_renders_without_nan_coordinates() {
        // a diverged trial (NaN value) may print "NaN" in the trials
        // table, but must never produce NaN SVG coordinates
        let study = Study::builder()
            .name("dash-nan")
            .sampler(Arc::new(RandomSampler::new(6)))
            .build()
            .unwrap();
        study
            .optimize(4, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                Ok(if x < 0.5 { f64::NAN } else { x })
            })
            .unwrap();
        let html = render_html(&study).unwrap();
        // attribute coordinates are quoted, polyline points comma-joined
        assert!(!html.contains("'NaN'"), "NaN attribute coordinate: {html}");
        assert!(
            !html.contains("NaN,") && !html.contains(",NaN"),
            "NaN polyline coordinate: {html}"
        );
    }

    #[test]
    fn trial_rows_carry_timestamps_durations_and_retries() {
        let study = demo_study();
        let html = render_html(&study).unwrap();
        for th in ["<th>start</th>", "<th>end</th>", "<th>duration</th>", "<th>retries</th>"] {
            assert!(html.contains(th), "missing column {th}");
        }
        // in-memory trials are stamped, so durations must render
        assert!(
            html.contains("ms</td>") || html.contains("s</td>"),
            "no rendered duration found"
        );
        // completed trials all have retry count 0 here
        assert!(html.contains("<td>0</td>"));
    }

    #[test]
    fn telemetry_and_resilience_sections_render() {
        // a study without telemetry renders neither section
        let plain = demo_study();
        let html = render_html(&plain).unwrap();
        assert!(!html.contains("Telemetry:"));
        assert!(!html.contains("<h2>Resilience</h2>"));
        // with telemetry + a retry layer both appear, populated
        let tel = Telemetry::new();
        let study = Study::builder()
            .name("dash-tel")
            .sampler(Arc::new(RandomSampler::new(1)))
            .resilience(ResilienceConfig::new())
            .telemetry(tel)
            .build()
            .unwrap();
        study
            .optimize(10, |t| {
                let x = t.suggest_float("x", -2.0, 2.0)?;
                Ok(x * x)
            })
            .unwrap();
        let html = render_html(&study).unwrap();
        assert!(html.contains("Telemetry: storage ops"), "{html}");
        assert!(html.contains("<td>create_trial</td>"), "{html}");
        assert!(html.contains("Telemetry: spans"), "{html}");
        assert!(html.contains("<td>study.ask</td>"), "{html}");
        assert!(html.contains("<h2>Resilience</h2>"), "{html}");
        assert_eq!(html.matches("<table>").count(), html.matches("</table>").count());
    }

    #[test]
    fn multi_objective_study_renders_pareto_front() {
        let study = Study::builder()
            .name("dash-moo")
            .directions(&[StudyDirection::Minimize, StudyDirection::Minimize])
            .sampler(Arc::new(RandomSampler::new(3)))
            .build()
            .unwrap();
        study
            .optimize_multi(20, |t| {
                let x = t.suggest_float("x", 0.0, 1.0)?;
                let y = t.suggest_float("y", 0.0, 1.0)?;
                Ok(vec![x + 0.1 * y, 1.0 - x + 0.1 * y])
            })
            .unwrap();
        let html = render_html(&study).unwrap();
        assert!(html.contains("minimize, minimize"), "all directions in the title");
        assert!(html.contains("Pareto front ("), "front section present");
        assert!(html.contains("front in blue"), "2-objective scatter present");
        // multi-objective value cells join both objectives
        assert!(html.contains("; "), "joined objective values");
        assert_eq!(html.matches("<table>").count(), html.matches("</table>").count());
        assert_eq!(html.matches("<svg").count(), html.matches("</svg>").count());
        // single-objective studies render no front section
        let single = demo_study();
        assert!(!render_html(&single).unwrap().contains("Pareto front ("));
    }
}
