//! Workloads: the benchmark problems and simulated applications the
//! paper's evaluation section runs the framework on.

pub mod distsim;
pub mod evalset;
pub mod ffmpeg_sim;
pub mod hpl_sim;
pub mod rocksdb_sim;
pub mod svhn_surrogate;
