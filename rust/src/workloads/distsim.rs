//! Virtual-time simulator for distributed studies (Fig 11/12 harness).
//!
//! The paper's distributed experiments measure *wallclock* convergence
//! with 1–8 workers sharing one study. Re-running them in real time would
//! take days; instead this module simulates the exact asynchronous
//! execution on a virtual clock: each worker is an independent timeline,
//! trials advance step by step (each step costs simulated seconds), and
//! the globally-earliest worker always acts next — which reproduces the
//! interleaving a real cluster would produce, deterministically.
//!
//! The simulator drives a real [`Study`] (real storage, sampler, pruner);
//! only *time* is virtual.

use crate::core::{OptunaError, TrialState};
use crate::study::{Study, TrialOutcome};
use crate::trial::{Trial, TrialApi};

/// A step-wise workload: maps a started trial to a curve of
/// (per-step error, per-step cost) plus a final objective value.
pub trait StepWorkload {
    /// Called once when a trial starts; suggests parameters and returns a
    /// per-trial state object.
    fn start(&self, trial: &mut Trial<'_>) -> Result<Box<dyn TrialRun>, OptunaError>;
}

/// Per-trial execution state.
pub trait TrialRun {
    /// Total steps a full (unpruned) trial takes.
    fn max_steps(&self) -> u64;
    /// Advance to `step` (1-based, monotonic); returns (value, seconds).
    fn step(&mut self, step: u64) -> (f64, f64);
    /// Final objective value after the last executed step.
    fn final_value(&mut self) -> f64;
}

/// One sampled point of the convergence trajectory.
#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    /// Virtual seconds since study start.
    pub time: f64,
    /// Trials completed (any state) when the best changed.
    pub n_finished: u64,
    /// Best completed objective value so far.
    pub best: f64,
}

/// Result of one simulated study.
#[derive(Debug)]
pub struct SimResult {
    pub trace: Vec<TracePoint>,
    pub n_complete: u64,
    pub n_pruned: u64,
    /// Best value at budget end (+inf if nothing completed).
    pub best: f64,
}

/// Run `study` with `n_workers` simulated workers for `budget` virtual
/// seconds. Workers act in global virtual-time order; pruning decisions
/// happen at every step through the study's pruner, exactly as in the
/// real optimize loop (Fig 5 pattern).
pub fn simulate(
    study: &Study,
    workload: &dyn StepWorkload,
    n_workers: usize,
    budget: f64,
) -> Result<SimResult, OptunaError> {
    struct WorkerState<'s> {
        clock: f64,
        run: Option<(Trial<'s>, Box<dyn TrialRun>, u64)>, // (trial, state, next step)
    }
    let mut workers: Vec<WorkerState> = (0..n_workers)
        .map(|_| WorkerState { clock: 0.0, run: None })
        .collect();
    let mut trace: Vec<TracePoint> = Vec::new();
    let mut best = f64::INFINITY;
    let sign = study.direction.min_sign();
    let mut n_complete = 0u64;
    let mut n_pruned = 0u64;
    let mut n_finished = 0u64;

    loop {
        // earliest worker acts next
        let (wi, _) = workers
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.clock.partial_cmp(&b.1.clock).unwrap())
            .unwrap();
        if workers[wi].clock >= budget {
            break; // every other worker is at least this late
        }
        let w = &mut workers[wi];
        match w.run.take() {
            None => {
                // start a new trial
                let mut trial = study.ask()?;
                let run = workload.start(&mut trial)?;
                w.run = Some((trial, run, 1));
            }
            Some((mut trial, mut run, step)) => {
                let (value, secs) = run.step(step);
                w.clock += secs;
                trial.report(step, value)?;
                let pruned = trial.should_prune()?;
                let done = step >= run.max_steps();
                if pruned && !done {
                    study.tell(trial, TrialOutcome::Pruned)?;
                    n_pruned += 1;
                    n_finished += 1;
                } else if done {
                    let v = run.final_value();
                    study.tell(trial, TrialOutcome::Complete(v))?;
                    n_complete += 1;
                    n_finished += 1;
                    if sign * v < sign * best || best.is_infinite() {
                        best = v;
                        trace.push(TracePoint { time: w.clock, n_finished, best });
                    }
                } else {
                    w.run = Some((trial, run, step + 1));
                }
            }
        }
    }
    // abandon still-running trials (budget exhausted mid-trial)
    for w in workers {
        if let Some((trial, _, _)) = w.run {
            study.tell(trial, TrialOutcome::Failed("budget exhausted".into()))?;
        }
    }
    Ok(SimResult { trace, n_complete, n_pruned, best })
}

/// Best-so-far value at a given virtual time (steps through the trace).
pub fn best_at(trace: &[TracePoint], time: f64) -> Option<f64> {
    trace
        .iter()
        .take_while(|p| p.time <= time)
        .last()
        .map(|p| p.best)
}

/// Best-so-far value after the first `n` finished trials.
pub fn best_after_trials(trace: &[TracePoint], n: u64) -> Option<f64> {
    trace
        .iter()
        .take_while(|p| p.n_finished <= n)
        .last()
        .map(|p| p.best)
}

/// Count trials by state in a study (reporting convenience).
pub fn state_counts(study: &Study) -> Result<(u64, u64, u64), OptunaError> {
    let trials = study.trials()?;
    let c = trials.iter().filter(|t| t.state == TrialState::Complete).count() as u64;
    let p = trials.iter().filter(|t| t.state == TrialState::Pruned).count() as u64;
    let f = trials.iter().filter(|t| t.state == TrialState::Failed).count() as u64;
    Ok((c, p, f))
}

/// The Fig 11/12 workload: the SVHN learning-curve surrogate as a
/// [`StepWorkload`].
pub struct SurrogateWorkload;

struct SurrogateRun {
    curve: crate::workloads::svhn_surrogate::TrialCurve,
    last: f64,
}

impl StepWorkload for SurrogateWorkload {
    fn start(&self, trial: &mut Trial<'_>) -> Result<Box<dyn TrialRun>, OptunaError> {
        let p = crate::workloads::svhn_surrogate::suggest_params(trial)?;
        Ok(Box::new(SurrogateRun { curve: p.curve(trial.number()), last: 0.9 }))
    }
}

impl TrialRun for SurrogateRun {
    fn max_steps(&self) -> u64 {
        crate::workloads::svhn_surrogate::MAX_STEPS
    }
    fn step(&mut self, step: u64) -> (f64, f64) {
        self.last = self.curve.err_at(step);
        (self.last, self.curve.step_seconds)
    }
    fn final_value(&mut self) -> f64 {
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prelude::*;
    use std::sync::Arc;

    fn run(n_workers: usize, pruner: Arc<dyn Pruner>, budget: f64, seed: u64) -> SimResult {
        let study = Study::builder()
            .name("sim")
            .sampler(Arc::new(TpeSampler::new(seed)))
            .pruner(pruner)
            .build()
            .unwrap();
        simulate(&study, &SurrogateWorkload, n_workers, budget).unwrap()
    }

    #[test]
    fn no_pruning_completes_a_handful_of_trials() {
        // 4h budget / ~400 s per trial ≈ 36 trials (paper's no-pruning arm)
        let r = run(1, Arc::new(NopPruner), 4.0 * 3600.0, 0);
        assert_eq!(r.n_pruned, 0);
        assert!((20..60).contains(&(r.n_complete as i64)), "{}", r.n_complete);
        assert!(r.best < 0.5);
    }

    #[test]
    fn asha_explores_order_of_magnitude_more() {
        let nop = run(1, Arc::new(NopPruner), 4.0 * 3600.0, 1);
        let asha = run(1, Arc::new(AshaPruner::new()), 4.0 * 3600.0, 1);
        let total_asha = asha.n_complete + asha.n_pruned;
        let total_nop = nop.n_complete + nop.n_pruned;
        assert!(
            total_asha > 5 * total_nop,
            "asha {total_asha} vs nop {total_nop}"
        );
        assert!(asha.n_pruned > asha.n_complete);
    }

    #[test]
    fn more_workers_do_more_work_in_same_time() {
        let w1 = run(1, Arc::new(NopPruner), 2.0 * 3600.0, 2);
        let w4 = run(4, Arc::new(NopPruner), 2.0 * 3600.0, 2);
        let t1 = w1.n_complete;
        let t4 = w4.n_complete;
        assert!(t4 > 3 * t1, "w1={t1} w4={t4}");
    }

    #[test]
    fn trace_is_monotone_in_time_and_best() {
        let r = run(2, Arc::new(AshaPruner::new()), 3600.0, 3);
        for w in r.trace.windows(2) {
            assert!(w[0].time <= w[1].time);
            assert!(w[1].best <= w[0].best); // minimize
        }
        assert_eq!(best_at(&r.trace, f64::INFINITY), Some(r.best));
        assert_eq!(best_at(&r.trace, -1.0), None);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(4, Arc::new(AshaPruner::new()), 3600.0, 7);
        let b = run(4, Arc::new(AshaPruner::new()), 3600.0, 7);
        assert_eq!(a.n_complete, b.n_complete);
        assert_eq!(a.n_pruned, b.n_pruned);
        assert_eq!(a.best, b.best);
    }
}
