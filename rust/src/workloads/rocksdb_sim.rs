//! RocksDB tuning workload (§6): an analytic LSM-tree cost model over the
//! same 34-parameter space the paper explored.
//!
//! The paper's experiment applies a fixed operation set (store / search /
//! delete over 500 k × 10 KB files) and tunes 34 of RocksDB's >100
//! options; the default configuration takes 372 s on their HDD, the tuned
//! one 30 s, and with pruning Optuna explores 937 configurations in 4 h
//! vs 39 with a timeout and 2 without. What the experiment demonstrates
//! is *pruning under widely-varying trial cost with many conditional
//! parameters* — which survives substitution by a cost model that
//! preserves (a) the default-vs-tuned gap, (b) strong parameter
//! interactions, and (c) cost spread across configurations.
//!
//! The model decomposes runtime into write (memtable + flush + compaction
//! write-amplification), read (block cache + bloom + index), and delete
//! phases, evaluated in [`N_CHUNKS`] progressive chunks so pruners can
//! stop a slow configuration early.

use crate::core::OptunaError;
use crate::trial::TrialApi;

/// Progress reports per evaluation (pruning granularity).
pub const N_CHUNKS: u64 = 16;

/// The tuned subset of RocksDB options (34 parameters).
#[derive(Debug, Clone)]
pub struct RocksDbConfig {
    // --- memtable / write path (8)
    pub write_buffer_mb: i64,
    pub max_write_buffer_number: i64,
    pub min_write_buffer_number_to_merge: i64,
    pub max_background_compactions: i64,
    pub max_background_flushes: i64,
    pub max_subcompactions: i64,
    pub delayed_write_rate_mb: i64,
    pub memtable_prefix_bloom_ratio: f64,
    // --- level shape (7)
    pub level0_file_num_compaction_trigger: i64,
    pub level0_slowdown_writes_trigger: i64,
    pub level0_stop_writes_trigger: i64,
    pub num_levels: i64,
    pub target_file_size_mb: i64,
    pub max_bytes_for_level_base_mb: i64,
    pub max_bytes_for_level_multiplier: f64,
    // --- table / read path (9)
    pub block_size_kb: i64,
    pub block_cache_mb: i64,
    pub bloom_bits_per_key: i64,
    pub cache_index_and_filter_blocks: bool,
    pub optimize_filters_for_hits: bool,
    pub max_open_files: i64,
    pub table_cache_numshardbits: i64,
    pub compaction_readahead_kb: i64,
    pub pin_l0_filter_and_index: bool,
    // --- compression (3)
    pub compression: String,
    pub compression_level: i64,
    pub bottommost_compression: String,
    // --- io (7)
    pub compaction_style: String,
    pub use_direct_reads: bool,
    pub use_direct_io_for_flush: bool,
    pub allow_mmap_reads: bool,
    pub allow_mmap_writes: bool,
    pub bytes_per_sync_mb: i64,
    pub wal_bytes_per_sync_mb: i64,
}

impl RocksDbConfig {
    /// RocksDB's out-of-the-box defaults (the paper's 372 s baseline).
    pub fn default_config() -> RocksDbConfig {
        RocksDbConfig {
            write_buffer_mb: 64,
            max_write_buffer_number: 2,
            min_write_buffer_number_to_merge: 1,
            max_background_compactions: 1,
            max_background_flushes: 1,
            max_subcompactions: 1,
            delayed_write_rate_mb: 16,
            memtable_prefix_bloom_ratio: 0.0,
            level0_file_num_compaction_trigger: 4,
            level0_slowdown_writes_trigger: 20,
            level0_stop_writes_trigger: 36,
            num_levels: 7,
            target_file_size_mb: 64,
            max_bytes_for_level_base_mb: 256,
            max_bytes_for_level_multiplier: 10.0,
            block_size_kb: 4,
            block_cache_mb: 8,
            bloom_bits_per_key: 0,
            cache_index_and_filter_blocks: false,
            optimize_filters_for_hits: false,
            max_open_files: 1000,
            table_cache_numshardbits: 6,
            compaction_readahead_kb: 0,
            pin_l0_filter_and_index: false,
            compression: "snappy".into(),
            compression_level: 0,
            bottommost_compression: "snappy".into(),
            compaction_style: "level".into(),
            use_direct_reads: false,
            use_direct_io_for_flush: false,
            allow_mmap_reads: false,
            allow_mmap_writes: false,
            bytes_per_sync_mb: 0,
            wal_bytes_per_sync_mb: 0,
        }
    }

    /// Number of tuned parameters (paper: 34).
    pub const N_PARAMS: usize = 34;
}

/// Suggest all 34 parameters through the define-by-run API (conditional:
/// compression_level only exists when a leveled codec is chosen —
/// the kind of space the paper's API motivates).
pub fn suggest_config<T: TrialApi>(t: &mut T) -> Result<RocksDbConfig, OptunaError> {
    let compression = t.suggest_categorical("compression", &["none", "snappy", "lz4", "zlib", "zstd"])?;
    let compression_level = if compression == "zlib" || compression == "zstd" {
        t.suggest_int("compression_level", 1, 9)?
    } else {
        0
    };
    Ok(RocksDbConfig {
        write_buffer_mb: t.suggest_int_log("write_buffer_mb", 4, 512)?,
        max_write_buffer_number: t.suggest_int("max_write_buffer_number", 1, 8)?,
        min_write_buffer_number_to_merge: t.suggest_int("min_write_buffer_number_to_merge", 1, 4)?,
        max_background_compactions: t.suggest_int("max_background_compactions", 1, 8)?,
        max_background_flushes: t.suggest_int("max_background_flushes", 1, 4)?,
        max_subcompactions: t.suggest_int("max_subcompactions", 1, 8)?,
        delayed_write_rate_mb: t.suggest_int_log("delayed_write_rate_mb", 1, 256)?,
        memtable_prefix_bloom_ratio: t.suggest_float("memtable_prefix_bloom_ratio", 0.0, 0.3)?,
        level0_file_num_compaction_trigger: t.suggest_int("level0_file_num_compaction_trigger", 2, 16)?,
        level0_slowdown_writes_trigger: t.suggest_int("level0_slowdown_writes_trigger", 8, 64)?,
        level0_stop_writes_trigger: t.suggest_int("level0_stop_writes_trigger", 16, 128)?,
        num_levels: t.suggest_int("num_levels", 2, 8)?,
        target_file_size_mb: t.suggest_int_log("target_file_size_mb", 8, 512)?,
        max_bytes_for_level_base_mb: t.suggest_int_log("max_bytes_for_level_base_mb", 64, 2048)?,
        max_bytes_for_level_multiplier: t.suggest_float("max_bytes_for_level_multiplier", 4.0, 16.0)?,
        block_size_kb: t.suggest_int_log("block_size_kb", 1, 128)?,
        block_cache_mb: t.suggest_int_log("block_cache_mb", 4, 4096)?,
        bloom_bits_per_key: t.suggest_int("bloom_bits_per_key", 0, 20)?,
        cache_index_and_filter_blocks: t.suggest_categorical("cache_index_and_filter_blocks", &["false", "true"])? == "true",
        optimize_filters_for_hits: t.suggest_categorical("optimize_filters_for_hits", &["false", "true"])? == "true",
        max_open_files: t.suggest_int_log("max_open_files", 100, 100_000)?,
        table_cache_numshardbits: t.suggest_int("table_cache_numshardbits", 4, 10)?,
        compaction_readahead_kb: t.suggest_int("compaction_readahead_kb", 0, 2048)?,
        pin_l0_filter_and_index: t.suggest_categorical("pin_l0_filter_and_index", &["false", "true"])? == "true",
        compression,
        compression_level,
        bottommost_compression: t.suggest_categorical("bottommost_compression", &["none", "snappy", "zstd"])?,
        compaction_style: t.suggest_categorical("compaction_style", &["level", "universal", "fifo"])?,
        use_direct_reads: t.suggest_categorical("use_direct_reads", &["false", "true"])? == "true",
        use_direct_io_for_flush: t.suggest_categorical("use_direct_io_for_flush", &["false", "true"])? == "true",
        allow_mmap_reads: t.suggest_categorical("allow_mmap_reads", &["false", "true"])? == "true",
        allow_mmap_writes: t.suggest_categorical("allow_mmap_writes", &["false", "true"])? == "true",
        bytes_per_sync_mb: t.suggest_int("bytes_per_sync_mb", 0, 8)?,
        wal_bytes_per_sync_mb: t.suggest_int("wal_bytes_per_sync_mb", 0, 8)?,
    })
}

impl RocksDbConfig {
    /// Write-amplification factor of the level shape.
    fn write_amp(&self) -> f64 {
        match self.compaction_style.as_str() {
            "universal" => 1.6 + 4.0 / self.level0_file_num_compaction_trigger as f64,
            "fifo" => 1.15, // cheap writes, hopeless reads (modeled below)
            _ => {
                // leveled: WA ≈ levels × multiplier sensitivity
                let eff_levels = (self.num_levels as f64 - 1.0)
                    .min(5e6 * 0.01 / self.max_bytes_for_level_base_mb as f64 + 3.0);
                1.0 + eff_levels * (self.max_bytes_for_level_multiplier / 10.0).sqrt()
            }
        }
    }

    /// Seconds for the write phase of the full operation set.
    fn write_seconds(&self) -> f64 {
        // Larger memtables flush less; more background jobs overlap IO.
        let memtable_eff = (64.0 / self.write_buffer_mb as f64).powf(0.45)
            / (self.max_write_buffer_number as f64).powf(0.25);
        let parallel = 1.0
            / (0.35
                + 0.65
                    / ((self.max_background_compactions + self.max_background_flushes) as f64
                        / 2.0)
                        .powf(0.6));
        let stall = {
            // low L0 slowdown triggers cause write stalls
            let slack = self.level0_slowdown_writes_trigger as f64
                / self.level0_file_num_compaction_trigger as f64;
            1.0 + (2.0 / slack).min(2.0)
        };
        let codec = match self.compression.as_str() {
            "none" => 0.9,
            "snappy" => 1.0,
            "lz4" => 0.95,
            "zstd" => 1.1 + 0.05 * self.compression_level as f64,
            _ => 1.35 + 0.12 * self.compression_level as f64, // zlib
        };
        let sync = 1.0 + 0.05 * (self.bytes_per_sync_mb + self.wal_bytes_per_sync_mb) as f64 / 8.0;
        let mmap_w = if self.allow_mmap_writes { 0.95 } else { 1.0 };
        35.0 * self.write_amp().sqrt() * memtable_eff * parallel * stall * codec * sync
            * mmap_w
            / (self.max_subcompactions as f64).powf(0.15)
    }

    /// Seconds for the read (search) phase.
    fn read_seconds(&self) -> f64 {
        // Bloom filters remove most negative-lookup IO; block cache serves
        // hot blocks; small block size wastes index, huge wastes IO.
        let bloom = if self.bloom_bits_per_key == 0 {
            2.6
        } else {
            1.0 + 1.2 * (10.0 / (self.bloom_bits_per_key as f64 + 4.0) - 0.6).max(0.0)
        };
        let cache = (256.0 / (self.block_cache_mb as f64 + 32.0)).powf(0.5).clamp(0.35, 2.4);
        let bs = {
            let b = self.block_size_kb as f64;
            1.0 + 0.25 * ((b / 16.0).ln()).abs()
        };
        let idx = if self.cache_index_and_filter_blocks {
            if self.pin_l0_filter_and_index { 0.9 } else { 1.05 }
        } else {
            1.0
        };
        let hits = if self.optimize_filters_for_hits { 0.93 } else { 1.0 };
        let files = 1.0 + (1000.0 / self.max_open_files as f64).min(1.5) * 0.4
            - 0.01 * (self.table_cache_numshardbits as f64 - 6.0);
        let direct = if self.use_direct_reads { 0.92 } else { 1.0 };
        let mmap = if self.allow_mmap_reads && self.use_direct_reads {
            1.25 // conflicting hints
        } else if self.allow_mmap_reads {
            0.96
        } else {
            1.0
        };
        let style = if self.compaction_style == "fifo" { 2.2 } else { 1.0 };
        let ra = 1.0 - 0.03 * (self.compaction_readahead_kb as f64 / 2048.0);
        let mpb = 1.0 - 0.25 * self.memtable_prefix_bloom_ratio.min(0.2);
        18.0 * bloom * cache * bs * idx * hits * files * direct * mmap * style * ra * mpb
    }

    /// Seconds for the delete phase.
    fn delete_seconds(&self) -> f64 {
        let wa = self.write_amp();
        let style = if self.compaction_style == "universal" { 0.9 } else { 1.0 };
        5.0 * wa.powf(0.4) * style
    }

    /// Total simulated runtime of the full operation set (the objective;
    /// minimized).
    pub fn total_seconds(&self) -> f64 {
        self.write_seconds() + self.read_seconds() + self.delete_seconds()
    }

    /// Runtime of chunk `i` of [`N_CHUNKS`] (chunks are uniform; the
    /// cumulative sum is what a pruner sees via report()).
    pub fn chunk_seconds(&self) -> f64 {
        self.total_seconds() / N_CHUNKS as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_near_372_seconds() {
        let d = RocksDbConfig::default_config().total_seconds();
        assert!((320.0..420.0).contains(&d), "default = {d}");
    }

    #[test]
    fn hand_tuned_config_under_40_seconds() {
        let tuned = RocksDbConfig {
            write_buffer_mb: 512,
            max_write_buffer_number: 6,
            max_background_compactions: 8,
            max_background_flushes: 4,
            max_subcompactions: 8,
            level0_file_num_compaction_trigger: 8,
            level0_slowdown_writes_trigger: 64,
            level0_stop_writes_trigger: 128,
            num_levels: 4,
            max_bytes_for_level_base_mb: 2048,
            max_bytes_for_level_multiplier: 8.0,
            block_size_kb: 16,
            block_cache_mb: 4096,
            bloom_bits_per_key: 14,
            cache_index_and_filter_blocks: true,
            pin_l0_filter_and_index: true,
            optimize_filters_for_hits: true,
            max_open_files: 100_000,
            compression: "lz4".into(),
            compaction_style: "universal".into(),
            use_direct_reads: true,
            allow_mmap_reads: false,
            memtable_prefix_bloom_ratio: 0.2,
            compaction_readahead_kb: 2048,
            ..RocksDbConfig::default_config()
        };
        let s = tuned.total_seconds();
        assert!(s < 40.0, "tuned = {s}");
        assert!(s > 10.0, "suspiciously fast: {s}");
        // the paper's headline shape: an order-of-magnitude speedup
        let default = RocksDbConfig::default_config().total_seconds();
        assert!(default / s > 8.0, "speedup = {}", default / s);
    }

    #[test]
    fn cost_varies_widely_across_space() {
        use crate::prelude::*;
        use std::sync::Arc;
        let study = Study::builder()
            .name("rdb-spread")
            .sampler(Arc::new(RandomSampler::new(0)))
            .build()
            .unwrap();
        let costs = std::sync::Mutex::new(Vec::new());
        study
            .optimize(60, |t| {
                let cfg = suggest_config(t)?;
                let s = cfg.total_seconds();
                costs.lock().unwrap().push(s);
                Ok(s)
            })
            .unwrap();
        let costs = costs.into_inner().unwrap();
        let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = costs.iter().cloned().fold(0.0f64, f64::max);
        assert!(max / min > 3.0, "spread {min}..{max}");
        assert!(costs.iter().all(|c| c.is_finite() && *c > 0.0));
    }

    #[test]
    fn bloom_bits_help_reads() {
        let mut a = RocksDbConfig::default_config();
        a.bloom_bits_per_key = 0;
        let mut b = RocksDbConfig::default_config();
        b.bloom_bits_per_key = 12;
        assert!(b.read_seconds() < a.read_seconds());
    }

    #[test]
    fn fifo_trades_writes_for_reads() {
        let mut f = RocksDbConfig::default_config();
        f.compaction_style = "fifo".into();
        let d = RocksDbConfig::default_config();
        assert!(f.write_seconds() < d.write_seconds());
        assert!(f.read_seconds() > d.read_seconds());
    }

    #[test]
    fn chunks_sum_to_total() {
        let c = RocksDbConfig::default_config();
        let sum = c.chunk_seconds() * N_CHUNKS as f64;
        assert!((sum - c.total_seconds()).abs() < 1e-9);
    }

    #[test]
    fn param_count_is_34() {
        // count the suggest calls by running once through a recording trial
        use crate::prelude::*;
        use std::sync::Arc;
        let study = Study::builder()
            .name("rdb-params")
            .sampler(Arc::new(RandomSampler::new(1)))
            .build()
            .unwrap();
        study
            .optimize(20, |t| {
                let cfg = suggest_config(t)?;
                Ok(cfg.total_seconds())
            })
            .unwrap();
        for t in study.trials().unwrap() {
            let n = t.params.len();
            // 34 params; compression_level only on zlib/zstd branches
            let has_level = t.params.contains_key("compression_level");
            let expect = if has_level { 34 } else { 33 };
            assert_eq!(n, expect, "trial {} had {n}", t.number);
        }
    }
}
