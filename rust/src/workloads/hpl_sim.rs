//! High-Performance Linpack tuning workload (§6): a performance model of
//! HPL on a 16-node cluster (the MN-1b substitution).
//!
//! HPL's achieved GFLOPS depends strongly on the problem size N, the
//! panel block size NB, the P×Q process grid, and the broadcast/lookahead
//! algorithms; the model below reproduces the well-known sensitivities
//! (NB sweet spot from cache/panel trade-off, flat-ish N saturation,
//! tall-thin grids hurting broadcast, algorithmic variants worth a few
//! percent). Objective: maximize GFLOPS.

use crate::core::OptunaError;
use crate::trial::TrialApi;

/// Cluster peak in GFLOPS (16 nodes × 500 GFLOPS).
pub const PEAK_GFLOPS: f64 = 8000.0;
/// Total processes (P×Q must equal this).
pub const N_PROCS: i64 = 16;

/// One HPL configuration.
#[derive(Debug, Clone)]
pub struct HplConfig {
    pub n: i64,
    pub nb: i64,
    pub p: i64,
    pub q: i64,
    pub bcast: String,
    pub depth: i64,
    pub swap: String,
    pub lookahead: i64,
}

/// Suggest the HPL space. P is drawn from the divisors of 16; Q follows.
pub fn suggest_config<T: TrialApi>(t: &mut T) -> Result<HplConfig, OptunaError> {
    let p_str = t.suggest_categorical("p", &["1", "2", "4", "8", "16"])?;
    let p: i64 = p_str.parse().unwrap();
    Ok(HplConfig {
        n: t.suggest_int("n_thousands", 10, 120)? * 1000,
        nb: t.suggest_int("nb", 32, 512)?,
        p,
        q: N_PROCS / p,
        bcast: t.suggest_categorical("bcast", &["1rg", "1rM", "2rg", "2rM", "blonG", "blonM"])?,
        depth: t.suggest_int("depth", 0, 1)?,
        swap: t.suggest_categorical("swap", &["bin-exch", "long", "mix"])?,
        lookahead: t.suggest_int("lookahead", 0, 2)?,
    })
}

impl HplConfig {
    /// Modeled sustained GFLOPS (maximize).
    pub fn gflops(&self) -> f64 {
        // N saturation: efficiency rises with memory utilization
        let n_eff = {
            let frac = self.n as f64 / 120_000.0;
            (0.55 + 0.45 * frac.powf(0.35)).min(1.0)
        };
        // NB sweet spot near 192 (cache blocking vs panel overhead)
        let nb_eff = {
            let x = (self.nb as f64 / 192.0).ln();
            (1.0 - 0.16 * x * x).max(0.4)
        };
        // grid: near-square grids broadcast best; Q >= P preferred
        let grid_eff = {
            let ratio = self.q as f64 / self.p as f64; // 16→1/16 .. 16
            let lr = (ratio / 4.0).ln(); // optimum around Q/P = 4 (2x8? use 4)
            (1.0 - 0.05 * lr * lr).max(0.6)
        };
        let bcast_eff = match self.bcast.as_str() {
            "1rM" => 1.00,
            "1rg" => 0.985,
            "2rM" => 0.995,
            "2rg" => 0.98,
            "blonM" => 0.99,
            _ => 0.975,
        };
        let depth_eff = if self.depth == 1 { 1.005 } else { 1.0 };
        let swap_eff = match self.swap.as_str() {
            "mix" => 1.0,
            "long" => 0.995,
            _ => 0.985,
        };
        let la_eff = match self.lookahead {
            1 => 1.01,
            2 => 1.005, // deeper lookahead costs memory
            _ => 1.0,
        };
        PEAK_GFLOPS * n_eff * nb_eff * grid_eff * bcast_eff * depth_eff * swap_eff * la_eff
    }

    /// Simulated wallclock of one benchmark run (2/3·N³ flops).
    pub fn run_seconds(&self) -> f64 {
        let flops = 2.0 / 3.0 * (self.n as f64).powi(3);
        flops / (self.gflops() * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> HplConfig {
        HplConfig {
            n: 100_000,
            nb: 192,
            p: 2,
            q: 8,
            bcast: "1rM".into(),
            depth: 1,
            swap: "mix".into(),
            lookahead: 1,
        }
    }

    #[test]
    fn good_config_near_peak() {
        let g = base().gflops();
        assert!(g > 0.85 * PEAK_GFLOPS, "g={g}");
        assert!(g <= PEAK_GFLOPS * 1.03);
    }

    #[test]
    fn tiny_problem_is_inefficient() {
        let small = HplConfig { n: 10_000, ..base() };
        assert!(small.gflops() < 0.75 * PEAK_GFLOPS);
    }

    #[test]
    fn extreme_nb_hurts() {
        let tiny_nb = HplConfig { nb: 32, ..base() };
        let huge_nb = HplConfig { nb: 512, ..base() };
        assert!(tiny_nb.gflops() < base().gflops());
        assert!(huge_nb.gflops() < base().gflops());
    }

    #[test]
    fn degenerate_grid_hurts() {
        let flat = HplConfig { p: 1, q: 16, ..base() };
        let tall = HplConfig { p: 16, q: 1, ..base() };
        assert!(tall.gflops() < base().gflops());
        assert!(tall.gflops() < flat.gflops()); // Q >= P preferred
    }

    #[test]
    fn runtime_grows_with_n() {
        let small = HplConfig { n: 20_000, ..base() };
        assert!(base().run_seconds() > small.run_seconds());
    }

    #[test]
    fn study_finds_near_optimal() {
        use crate::prelude::*;
        use std::sync::Arc;
        let study = Study::builder()
            .name("hpl")
            .direction(StudyDirection::Maximize)
            .sampler(Arc::new(TpeSampler::new(0)))
            .build()
            .unwrap();
        study
            .optimize(120, |t| {
                let cfg = suggest_config(t)?;
                Ok(cfg.gflops())
            })
            .unwrap();
        let best = study.best_value().unwrap().unwrap();
        assert!(best > 0.9 * PEAK_GFLOPS, "best={best}");
    }
}
