//! The 56 test functions. Formulas follow the standard references
//! (Jamil & Yang 2013 survey; virtual library of simulation experiments).
//! Every function is minimized; `fmin`/`argmin` as documented there.

use super::TestFunction;
use std::f64::consts::{E, PI};

fn sq(v: f64) -> f64 {
    v * v
}

fn sum_sq(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum()
}

// ----- individual functions -------------------------------------------------

fn ackley(x: &[f64]) -> f64 {
    let n = x.len() as f64;
    let s1 = sum_sq(x) / n;
    let s2 = x.iter().map(|v| (2.0 * PI * v).cos()).sum::<f64>() / n;
    -20.0 * (-0.2 * s1.sqrt()).exp() - s2.exp() + 20.0 + E
}

fn adjiman(x: &[f64]) -> f64 {
    x[0].cos() * x[1].sin() - x[0] / (sq(x[1]) + 1.0)
}

fn alpine01(x: &[f64]) -> f64 {
    x.iter().map(|v| (v * v.sin() + 0.1 * v).abs()).sum()
}

fn alpine02(x: &[f64]) -> f64 {
    // product form; the global minimum on [0,10]^2 is attained with one
    // negative sin factor (Jamil & Yang 2013, f_6)
    x.iter().map(|v| v.sqrt() * v.sin()).product::<f64>()
}

fn beale(x: &[f64]) -> f64 {
    sq(1.5 - x[0] + x[0] * x[1])
        + sq(2.25 - x[0] + x[0] * sq(x[1]))
        + sq(2.625 - x[0] + x[0] * x[1].powi(3))
}

fn bird(x: &[f64]) -> f64 {
    x[0].sin() * (sq(1.0 - x[1].cos())).exp()
        + x[1].cos() * (sq(1.0 - x[0].sin())).exp()
        + sq(x[0] - x[1])
}

fn bohachevsky1(x: &[f64]) -> f64 {
    sq(x[0]) + 2.0 * sq(x[1]) - 0.3 * (3.0 * PI * x[0]).cos() - 0.4 * (4.0 * PI * x[1]).cos()
        + 0.7
}

fn booth(x: &[f64]) -> f64 {
    sq(x[0] + 2.0 * x[1] - 7.0) + sq(2.0 * x[0] + x[1] - 5.0)
}

fn branin(x: &[f64]) -> f64 {
    let a = 1.0;
    let b = 5.1 / (4.0 * PI * PI);
    let c = 5.0 / PI;
    let r = 6.0;
    let s = 10.0;
    let t = 1.0 / (8.0 * PI);
    a * sq(x[1] - b * sq(x[0]) + c * x[0] - r) + s * (1.0 - t) * x[0].cos() + s
}


fn bukin06(x: &[f64]) -> f64 {
    100.0 * (x[1] - 0.01 * sq(x[0])).abs().sqrt() + 0.01 * (x[0] + 10.0).abs()
}

fn carrom_table(x: &[f64]) -> f64 {
    let g = (1.0 - (sq(x[0]) + sq(x[1])).sqrt() / PI).abs();
    -(1.0 / 30.0) * (x[0].cos() * x[1].cos() * g.exp()).powi(2)
}


fn cigar(x: &[f64]) -> f64 {
    sq(x[0]) + 1e6 * x[1..].iter().map(|v| v * v).sum::<f64>()
}

fn cross_in_tray(x: &[f64]) -> f64 {
    let g = (100.0 - (sq(x[0]) + sq(x[1])).sqrt() / PI).abs();
    -0.0001 * ((x[0].sin() * x[1].sin() * g.exp()).abs() + 1.0).powf(0.1)
}

fn csendes(x: &[f64]) -> f64 {
    x.iter()
        .map(|v| {
            if *v == 0.0 {
                0.0
            } else {
                v.powi(6) * (2.0 + (1.0 / v).sin())
            }
        })
        .sum()
}

fn deb01(x: &[f64]) -> f64 {
    let n = x.len() as f64;
    -x.iter().map(|v| (5.0 * PI * v).sin().powi(6)).sum::<f64>() / n
}

fn deflected_corrugated_spring(x: &[f64]) -> f64 {
    let alpha = 5.0;
    let k = 5.0;
    let r2: f64 = x.iter().map(|v| sq(v - alpha)).sum();
    0.1 * r2 - (k * r2.sqrt()).cos() + 1.0
}

fn dixon_price(x: &[f64]) -> f64 {
    sq(x[0] - 1.0)
        + x.iter()
            .enumerate()
            .skip(1)
            .map(|(i, v)| (i as f64 + 1.0) * sq(2.0 * v * v - x[i - 1]))
            .sum::<f64>()
}

fn drop_wave(x: &[f64]) -> f64 {
    let r2 = sq(x[0]) + sq(x[1]);
    -(1.0 + (12.0 * r2.sqrt()).cos()) / (0.5 * r2 + 2.0)
}

fn easom(x: &[f64]) -> f64 {
    -x[0].cos() * x[1].cos() * (-(sq(x[0] - PI) + sq(x[1] - PI))).exp()
}


fn egg_holder(x: &[f64]) -> f64 {
    let a = -(x[1] + 47.0) * (x[1] + x[0] / 2.0 + 47.0).abs().sqrt().sin();
    let b = -x[0] * (x[0] - (x[1] + 47.0)).abs().sqrt().sin();
    a + b
}

fn exponential(x: &[f64]) -> f64 {
    -(-0.5 * sum_sq(x)).exp()
}

fn giunta(x: &[f64]) -> f64 {
    0.6 + x
        .iter()
        .map(|v| {
            let u = 16.0 / 15.0 * v - 1.0;
            u.sin() + sq(u.sin()) + (1.0 / 50.0) * (4.0 * u).sin()
        })
        .sum::<f64>()
}

fn goldstein_price(x: &[f64]) -> f64 {
    let (a, b) = (x[0], x[1]);
    let t1 = 1.0
        + sq(a + b + 1.0)
            * (19.0 - 14.0 * a + 3.0 * a * a - 14.0 * b + 6.0 * a * b + 3.0 * b * b);
    let t2 = 30.0
        + sq(2.0 * a - 3.0 * b)
            * (18.0 - 32.0 * a + 12.0 * a * a + 48.0 * b - 36.0 * a * b + 27.0 * b * b);
    t1 * t2
}

fn griewank(x: &[f64]) -> f64 {
    let s = sum_sq(x) / 4000.0;
    let p: f64 = x
        .iter()
        .enumerate()
        .map(|(i, v)| (v / ((i + 1) as f64).sqrt()).cos())
        .product();
    s - p + 1.0
}

fn hansen(x: &[f64]) -> f64 {
    let s1: f64 = (0..5)
        .map(|i| {
            let i = i as f64;
            (i + 1.0) * ((i + (i + 1.0) * x[0]).cos())
        })
        .sum();
    let s2: f64 = (0..5)
        .map(|j| {
            let j = j as f64;
            (j + 1.0) * ((j + 2.0 + (j + 1.0) * x[1]).cos())
        })
        .sum();
    s1 * s2
}

const H3_A: [[f64; 3]; 4] = [
    [3.0, 10.0, 30.0],
    [0.1, 10.0, 35.0],
    [3.0, 10.0, 30.0],
    [0.1, 10.0, 35.0],
];
const H3_P: [[f64; 3]; 4] = [
    [0.3689, 0.1170, 0.2673],
    [0.4699, 0.4387, 0.7470],
    [0.1091, 0.8732, 0.5547],
    [0.0381, 0.5743, 0.8828],
];
const H_C: [f64; 4] = [1.0, 1.2, 3.0, 3.2];

fn hartmann3(x: &[f64]) -> f64 {
    -(0..4)
        .map(|i| {
            let s: f64 = (0..3).map(|j| H3_A[i][j] * sq(x[j] - H3_P[i][j])).sum();
            H_C[i] * (-s).exp()
        })
        .sum::<f64>()
}

const H6_A: [[f64; 6]; 4] = [
    [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
    [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
    [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
    [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
];
const H6_P: [[f64; 6]; 4] = [
    [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
    [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
    [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
    [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
];

fn hartmann6(x: &[f64]) -> f64 {
    -(0..4)
        .map(|i| {
            let s: f64 = (0..6).map(|j| H6_A[i][j] * sq(x[j] - H6_P[i][j])).sum();
            H_C[i] * (-s).exp()
        })
        .sum::<f64>()
}

fn helical_valley(x: &[f64]) -> f64 {
    let theta = if x[0] >= 0.0 {
        (x[1] / x[0].max(1e-12)).atan() / (2.0 * PI)
    } else {
        ((x[1] / x[0].min(-1e-12)).atan() + PI) / (2.0 * PI)
    };
    100.0 * (sq(x[2] - 10.0 * theta) + sq((sq(x[0]) + sq(x[1])).sqrt() - 1.0)) + sq(x[2])
}

fn himmelblau(x: &[f64]) -> f64 {
    sq(sq(x[0]) + x[1] - 11.0) + sq(x[0] + sq(x[1]) - 7.0)
}

fn holder_table(x: &[f64]) -> f64 {
    let g = (1.0 - (sq(x[0]) + sq(x[1])).sqrt() / PI).abs();
    -(x[0].sin() * x[1].cos() * g.exp()).abs()
}

fn hosaki(x: &[f64]) -> f64 {
    (1.0 - 8.0 * x[0] + 7.0 * sq(x[0]) - 7.0 / 3.0 * x[0].powi(3) + 0.25 * x[0].powi(4))
        * sq(x[1])
        * (-x[1]).exp()
}

fn jennrich_sampson(x: &[f64]) -> f64 {
    (1..=10)
        .map(|i| {
            let i = i as f64;
            sq(2.0 + 2.0 * i - ((i * x[0]).exp() + (i * x[1]).exp()))
        })
        .sum()
}

fn langermann(x: &[f64]) -> f64 {
    const A: [[f64; 2]; 5] = [[3.0, 5.0], [5.0, 2.0], [2.0, 1.0], [1.0, 4.0], [7.0, 9.0]];
    const C: [f64; 5] = [1.0, 2.0, 5.0, 2.0, 3.0];
    -(0..5)
        .map(|i| {
            let s = sq(x[0] - A[i][0]) + sq(x[1] - A[i][1]);
            C[i] * (-s / PI).exp() * (PI * s).cos()
        })
        .sum::<f64>()
}

fn levy(x: &[f64]) -> f64 {
    let w: Vec<f64> = x.iter().map(|v| 1.0 + (v - 1.0) / 4.0).collect();
    let n = w.len();
    let mut s = sq((PI * w[0]).sin());
    for i in 0..n - 1 {
        s += sq(w[i] - 1.0) * (1.0 + 10.0 * sq((PI * w[i] + 1.0).sin()));
    }
    s + sq(w[n - 1] - 1.0) * (1.0 + sq((2.0 * PI * w[n - 1]).sin()))
}

fn levy13(x: &[f64]) -> f64 {
    sq((3.0 * PI * x[0]).sin())
        + sq(x[0] - 1.0) * (1.0 + sq((3.0 * PI * x[1]).sin()))
        + sq(x[1] - 1.0) * (1.0 + sq((2.0 * PI * x[1]).sin()))
}


fn mccormick(x: &[f64]) -> f64 {
    (x[0] + x[1]).sin() + sq(x[0] - x[1]) - 1.5 * x[0] + 2.5 * x[1] + 1.0
}

fn michalewicz(x: &[f64]) -> f64 {
    let m = 10.0;
    -x.iter()
        .enumerate()
        .map(|(i, v)| v.sin() * ((i as f64 + 1.0) * sq(*v) / PI).sin().powf(2.0 * m))
        .sum::<f64>()
}

fn miele_cantrell(x: &[f64]) -> f64 {
    (x[0].exp() - x[1]).powi(4)
        + 100.0 * (x[1] - x[2]).powi(6)
        + (x[2] - x[3]).tan().powi(4)
        + x[0].powi(8)
}


fn periodic(x: &[f64]) -> f64 {
    1.0 + sq(x[0].sin()) + sq(x[1].sin()) - 0.1 * (-(sq(x[0]) + sq(x[1]))).exp()
}

fn powell(x: &[f64]) -> f64 {
    sq(x[0] + 10.0 * x[1])
        + 5.0 * sq(x[2] - x[3])
        + (x[1] - 2.0 * x[2]).powi(4)
        + 10.0 * (x[0] - x[3]).powi(4)
}

fn qing(x: &[f64]) -> f64 {
    x.iter()
        .enumerate()
        .map(|(i, v)| sq(v * v - (i as f64 + 1.0)))
        .sum()
}

fn rastrigin(x: &[f64]) -> f64 {
    10.0 * x.len() as f64
        + x.iter()
            .map(|v| v * v - 10.0 * (2.0 * PI * v).cos())
            .sum::<f64>()
}

fn rosenbrock(x: &[f64]) -> f64 {
    x.windows(2)
        .map(|w| 100.0 * sq(w[1] - sq(w[0])) + sq(1.0 - w[0]))
        .sum()
}

fn salomon(x: &[f64]) -> f64 {
    let r = sum_sq(x).sqrt();
    1.0 - (2.0 * PI * r).cos() + 0.1 * r
}

fn schaffer2(x: &[f64]) -> f64 {
    let num = sq((sq(x[0]) - sq(x[1])).sin()) - 0.5;
    let den = sq(1.0 + 0.001 * (sq(x[0]) + sq(x[1])));
    0.5 + num / den
}

fn schwefel26(x: &[f64]) -> f64 {
    418.9829 * x.len() as f64
        - x.iter().map(|v| v * v.abs().sqrt().sin()).sum::<f64>()
}

fn shekel5(x: &[f64]) -> f64 {
    const A: [[f64; 4]; 5] = [
        [4.0, 4.0, 4.0, 4.0],
        [1.0, 1.0, 1.0, 1.0],
        [8.0, 8.0, 8.0, 8.0],
        [6.0, 6.0, 6.0, 6.0],
        [3.0, 7.0, 3.0, 7.0],
    ];
    const C: [f64; 5] = [0.1, 0.2, 0.2, 0.4, 0.4];
    -(0..5)
        .map(|i| {
            let s: f64 = (0..4).map(|j| sq(x[j] - A[i][j])).sum();
            1.0 / (s + C[i])
        })
        .sum::<f64>()
}

fn shubert(x: &[f64]) -> f64 {
    let s1: f64 = (1..=5)
        .map(|i| {
            let i = i as f64;
            i * ((i + 1.0) * x[0] + i).cos()
        })
        .sum();
    let s2: f64 = (1..=5)
        .map(|i| {
            let i = i as f64;
            i * ((i + 1.0) * x[1] + i).cos()
        })
        .sum();
    s1 * s2
}

fn six_hump_camel(x: &[f64]) -> f64 {
    (4.0 - 2.1 * sq(x[0]) + x[0].powi(4) / 3.0) * sq(x[0]) + x[0] * x[1]
        + (-4.0 + 4.0 * sq(x[1])) * sq(x[1])
}

fn sphere(x: &[f64]) -> f64 {
    sum_sq(x)
}

fn styblinski_tang(x: &[f64]) -> f64 {
    0.5 * x
        .iter()
        .map(|v| v.powi(4) - 16.0 * sq(*v) + 5.0 * v)
        .sum::<f64>()
}

fn trid(x: &[f64]) -> f64 {
    let s1: f64 = x.iter().map(|v| sq(v - 1.0)).sum();
    let s2: f64 = x.windows(2).map(|w| w[0] * w[1]).sum();
    s1 - s2
}

fn weierstrass(x: &[f64]) -> f64 {
    let (a, b, kmax) = (0.5f64, 3.0f64, 20);
    let n = x.len() as f64;
    let inner = |v: f64| -> f64 {
        (0..=kmax)
            .map(|k| a.powi(k) * (2.0 * PI * b.powi(k) * (v + 0.5)).cos())
            .sum()
    };
    let offset: f64 = (0..=kmax)
        .map(|k| a.powi(k) * (PI * b.powi(k)).cos())
        .sum();
    x.iter().map(|v| inner(*v)).sum::<f64>() - n * offset
}

fn zakharov(x: &[f64]) -> f64 {
    let s1 = sum_sq(x);
    let s2: f64 = x
        .iter()
        .enumerate()
        .map(|(i, v)| 0.5 * (i as f64 + 1.0) * v)
        .sum();
    s1 + sq(s2) + s2.powi(4)
}








fn trigonometric02(x: &[f64]) -> f64 {
    1.0 + x
        .iter()
        .map(|v| {
            8.0 * sq((7.0 * sq(v - 0.9)).sin())
                + 6.0 * sq((14.0 * sq(v - 0.9)).sin())
                + sq(v - 0.9)
        })
        .sum::<f64>()
}


fn wayburn_seader02(x: &[f64]) -> f64 {
    sq(1.613 - 4.0 * sq(x[0] - 0.3125) - 4.0 * sq(x[1] - 1.625)) + sq(x[1] - 1.0)
}

// ----- the registry ----------------------------------------------------------

/// All 56 problems with evalset-style bounds.
pub fn all_functions() -> Vec<TestFunction> {
    let c = TestFunction::cube;
    vec![
        c("ackley", 5, -15.0, 30.0, 0.0, Some(vec![0.0; 5]), ackley),
        TestFunction {
            name: "adjiman",
            dim: 2,
            bounds: vec![(-1.0, 2.0), (-1.0, 1.0)],
            fmin: -2.02181,
            argmin: Some(vec![2.0, 0.10578]),
            f: adjiman,
        },
        c("alpine01", 6, -10.0, 10.0, 0.0, Some(vec![0.0; 6]), alpine01),
        c("alpine02", 2, 0.0, 10.0, -6.1295, Some(vec![7.91705268, 4.81584232]), alpine02),
        c("beale", 2, -4.5, 4.5, 0.0, Some(vec![3.0, 0.5]), beale),
        c("bird", 2, -2.0 * PI, 2.0 * PI, -106.7645367, Some(vec![4.70104313, 3.15294601]), bird),
        c("bohachevsky1", 2, -100.0, 100.0, 0.0, Some(vec![0.0, 0.0]), bohachevsky1),
        c("booth", 2, -10.0, 10.0, 0.0, Some(vec![1.0, 3.0]), booth),
        TestFunction {
            name: "branin",
            dim: 2,
            bounds: vec![(-5.0, 10.0), (0.0, 15.0)],
            fmin: 0.39788735772973816,
            argmin: Some(vec![PI, 2.275]),
            f: branin,
        },
        TestFunction {
            name: "bukin06",
            dim: 2,
            bounds: vec![(-15.0, -5.0), (-3.0, 3.0)],
            fmin: 0.0,
            argmin: Some(vec![-10.0, 1.0]),
            f: bukin06,
        },
        c("carrom_table", 2, -10.0, 10.0, -24.15681551650653, Some(vec![9.646157266348881, 9.646134286497169]), carrom_table),
        c("cigar", 8, -10.0, 10.0, 0.0, Some(vec![0.0; 8]), cigar),
        c("cross_in_tray", 2, -10.0, 10.0, -2.062611870822739, Some(vec![1.349406685353340, 1.349406608602084]), cross_in_tray),
        c("csendes", 4, -1.0, 1.0, 0.0, Some(vec![0.0; 4]), csendes),
        c("deb01", 4, -1.0, 1.0, -1.0, Some(vec![0.1; 4]), deb01),
        c("deflected_corrugated_spring", 4, 0.0, 10.0, 0.0, Some(vec![5.0; 4]), deflected_corrugated_spring),
        c("dixon_price", 4, -10.0, 10.0, 0.0, Some(vec![
            1.0,
            2f64.powf(-0.5),
            2f64.powf(-0.75),
            2f64.powf(-0.875),
        ]), dixon_price),
        c("drop_wave", 2, -5.12, 5.12, -1.0, Some(vec![0.0, 0.0]), drop_wave),
        c("easom", 2, -100.0, 100.0, -1.0, Some(vec![PI, PI]), easom),
        c("egg_holder", 2, -512.0, 512.0, -959.6406627208506, Some(vec![512.0, 404.2318058008512]), egg_holder),
        c("exponential", 6, -1.0, 1.0, -1.0, Some(vec![0.0; 6]), exponential),
        c("giunta", 2, -1.0, 1.0, 0.06447042053690566, Some(vec![0.4673200277395354, 0.4673200169591304]), giunta),
        c("goldstein_price", 2, -2.0, 2.0, 3.0, Some(vec![0.0, -1.0]), goldstein_price),
        c("griewank", 6, -600.0, 600.0, 0.0, Some(vec![0.0; 6]), griewank),
        c("hansen", 2, -10.0, 10.0, -176.54179, None, hansen),
        c("hartmann3", 3, 0.0, 1.0, -3.8627797873327696, Some(vec![0.11461434, 0.55564885, 0.85254695]), hartmann3),
        c("hartmann6", 6, 0.0, 1.0, -3.322368011391339, Some(vec![
            0.20168952, 0.15001069, 0.47687398, 0.27533243, 0.31165162, 0.65730054,
        ]), hartmann6),
        c("helical_valley", 3, -10.0, 10.0, 0.0, Some(vec![1.0, 0.0, 0.0]), helical_valley),
        c("himmelblau", 2, -6.0, 6.0, 0.0, Some(vec![3.0, 2.0]), himmelblau),
        c("holder_table", 2, -10.0, 10.0, -19.20850256788675, Some(vec![8.055023472141116, 9.664590028909654]), holder_table),
        TestFunction {
            name: "hosaki",
            dim: 2,
            bounds: vec![(0.0, 5.0), (0.0, 6.0)],
            fmin: -2.3458115761013247,
            argmin: Some(vec![4.0, 2.0]),
            f: hosaki,
        },
        c("jennrich_sampson", 2, -1.0, 1.0, 124.36218235561473, Some(vec![0.257825, 0.257825]), jennrich_sampson),
        c("langermann", 2, 0.0, 10.0, -5.1621259, None, langermann),
        c("levy", 8, -10.0, 10.0, 0.0, Some(vec![1.0; 8]), levy),
        c("levy13", 2, -10.0, 10.0, 0.0, Some(vec![1.0, 1.0]), levy13),
        TestFunction {
            name: "mccormick",
            dim: 2,
            bounds: vec![(-1.5, 4.0), (-3.0, 4.0)],
            fmin: -1.913222954981037,
            argmin: Some(vec![-0.5471975602214493, -1.547197559268372]),
            f: mccormick,
        },
        c("michalewicz", 5, 0.0, PI, -4.687658, None, michalewicz),
        c("miele_cantrell", 4, -1.0, 1.0, 0.0, Some(vec![0.0, 1.0, 1.0, 1.0]), miele_cantrell),
        c("periodic", 2, -10.0, 10.0, 0.9, Some(vec![0.0, 0.0]), periodic),
        c("powell", 4, -4.0, 5.0, 0.0, Some(vec![0.0; 4]), powell),
        c("qing", 5, -500.0, 500.0, 0.0, Some(vec![
            1.0,
            2f64.sqrt(),
            3f64.sqrt(),
            2.0,
            5f64.sqrt(),
        ]), qing),
        c("rastrigin", 8, -5.12, 5.12, 0.0, Some(vec![0.0; 8]), rastrigin),
        c("rosenbrock", 5, -5.0, 10.0, 0.0, Some(vec![1.0; 5]), rosenbrock),
        c("salomon", 5, -100.0, 100.0, 0.0, Some(vec![0.0; 5]), salomon),
        c("schaffer2", 2, -100.0, 100.0, 0.0, Some(vec![0.0, 0.0]), schaffer2),
        c("schwefel26", 2, -500.0, 500.0, 0.0, Some(vec![420.968746, 420.968746]), schwefel26),
        c("shekel5", 4, 0.0, 10.0, -10.152719932456289, Some(vec![4.0, 4.0, 4.0, 4.0]), shekel5),
        c("shubert", 2, -10.0, 10.0, -186.7309, None, shubert),
        c("six_hump_camel", 2, -3.0, 3.0, -1.031628453489877, Some(vec![0.08984201368301331, -0.7126564032704135]), six_hump_camel),
        c("sphere", 7, -5.12, 5.12, 0.0, Some(vec![0.0; 7]), sphere),
        c("styblinski_tang", 5, -5.0, 5.0, -39.16616570377142 * 5.0, Some(vec![-2.903534018185960; 5]), styblinski_tang),
        c("trid", 6, -36.0, 36.0, -50.0, Some(vec![6.0, 10.0, 12.0, 12.0, 10.0, 6.0]), trid),
        c("trigonometric02", 5, -500.0, 500.0, 1.0, Some(vec![0.9; 5]), trigonometric02),
        c("wayburn_seader02", 2, -500.0, 500.0, 0.0, Some(vec![0.200138974728779, 1.0]), wayburn_seader02),
        c("weierstrass", 4, -0.5, 0.5, 0.0, Some(vec![0.0; 4]), weierstrass),
        c("zakharov", 5, -5.0, 10.0, 0.0, Some(vec![0.0; 5]), zakharov),
    ]
}
