//! Multi-objective benchmark functions: ZDT1, ZDT2 (Zitzler–Deb–Thiele
//! 2000) and DTLZ2 (Deb–Thiele–Laumanns–Zitzler 2002) — the standard
//! trio for exercising convergence *and* front-shape diversity (convex,
//! concave, spherical). They extend the evalset protocol of the scalar
//! suite (fixed bounds, known optima) to vector objectives; the `fig_moo`
//! bench, the CLI `optimize` command, and `rust/tests/moo.rs` all run
//! studies over them through [`MooFunction::objective`].

use crate::core::OptunaError;
use crate::trial::{Trial, TrialApi};

/// One multi-objective benchmark problem (all objectives minimized).
pub struct MooFunction {
    pub name: &'static str,
    pub dim: usize,
    pub n_obj: usize,
    /// (low, high) per dimension.
    pub bounds: Vec<(f64, f64)>,
    /// Reference point for hypervolume tracking: every objective value
    /// reachable from uniform random sampling stays strictly below it,
    /// so even an unconverged study scores a comparable number.
    pub ref_point: Vec<f64>,
    pub f: fn(&[f64]) -> Vec<f64>,
}

impl MooFunction {
    /// Evaluate, asserting dimension.
    pub fn eval(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.dim, "{}: wrong dimension", self.name);
        let v = (self.f)(x);
        debug_assert_eq!(v.len(), self.n_obj, "{}: wrong objective count", self.name);
        v
    }

    /// The standard study objective over this function: suggest one
    /// `x<ii>` parameter per dimension (zero-padded so CSV/param listings
    /// sort numerically) and evaluate. The single definition every runner
    /// (CLI, benches, acceptance tests) shares, so parameter naming can
    /// never drift between them.
    pub fn objective(&self, t: &mut Trial<'_>) -> Result<Vec<f64>, OptunaError> {
        let x: Vec<f64> = self
            .bounds
            .iter()
            .enumerate()
            .map(|(i, (lo, hi))| t.suggest_float(&format!("x{i:02}"), *lo, *hi))
            .collect::<Result<_, _>>()?;
        Ok(self.eval(&x))
    }
}

/// ZDT g-function: 1 + 9 · mean(x₁..) — 1 on the Pareto set (tail = 0).
fn zdt_g(x: &[f64]) -> f64 {
    let tail = &x[1..];
    1.0 + 9.0 * tail.iter().sum::<f64>() / tail.len() as f64
}

/// ZDT1 — convex Pareto front `f₂ = 1 − √f₁` at g = 1.
pub fn zdt1(x: &[f64]) -> Vec<f64> {
    let f1 = x[0];
    let g = zdt_g(x);
    vec![f1, g * (1.0 - (f1 / g).sqrt())]
}

/// ZDT2 — concave Pareto front `f₂ = 1 − f₁²` at g = 1.
pub fn zdt2(x: &[f64]) -> Vec<f64> {
    let f1 = x[0];
    let g = zdt_g(x);
    vec![f1, g * (1.0 - (f1 / g).powi(2))]
}

/// DTLZ2 (3 objectives) — spherical front `‖f‖ = 1` at g = 0.
pub fn dtlz2(x: &[f64]) -> Vec<f64> {
    use std::f64::consts::FRAC_PI_2;
    let g: f64 = x[2..].iter().map(|xi| (xi - 0.5) * (xi - 0.5)).sum();
    let (t0, t1) = (x[0] * FRAC_PI_2, x[1] * FRAC_PI_2);
    let scale = 1.0 + g;
    vec![
        scale * t0.cos() * t1.cos(),
        scale * t0.cos() * t1.sin(),
        scale * t0.sin(),
    ]
}

/// The multi-objective problem table. ZDT dims follow the original paper
/// (30); DTLZ2 uses the standard k = 10 tail (dim = 12).
pub fn moo_functions() -> Vec<MooFunction> {
    vec![
        MooFunction {
            name: "zdt1",
            dim: 30,
            n_obj: 2,
            bounds: vec![(0.0, 1.0); 30],
            // f1 <= 1, f2 <= g <= 10
            ref_point: vec![1.1, 11.0],
            f: zdt1,
        },
        MooFunction {
            name: "zdt2",
            dim: 30,
            n_obj: 2,
            bounds: vec![(0.0, 1.0); 30],
            ref_point: vec![1.1, 11.0],
            f: zdt2,
        },
        MooFunction {
            name: "dtlz2",
            dim: 12,
            n_obj: 3,
            bounds: vec![(0.0, 1.0); 12],
            // each objective <= 1 + g <= 3.5
            ref_point: vec![3.6, 3.6, 3.6],
            f: dtlz2,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn table_is_well_formed() {
        let fns = moo_functions();
        assert_eq!(fns.len(), 3);
        for f in &fns {
            assert_eq!(f.bounds.len(), f.dim, "{}", f.name);
            assert_eq!(f.ref_point.len(), f.n_obj, "{}", f.name);
            let mid: Vec<f64> = f.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
            let v = f.eval(&mid);
            assert_eq!(v.len(), f.n_obj, "{}", f.name);
            assert!(v.iter().all(|x| x.is_finite()), "{}: {v:?}", f.name);
        }
    }

    #[test]
    fn zdt_fronts_at_g_equals_one() {
        // tail = 0 puts the point on the true front
        for f1 in [0.0, 0.25, 0.5, 1.0] {
            let mut x = vec![0.0; 30];
            x[0] = f1;
            let v1 = zdt1(&x);
            assert!((v1[0] - f1).abs() < 1e-12);
            assert!((v1[1] - (1.0 - f1.sqrt())).abs() < 1e-12, "zdt1 front at {f1}");
            let v2 = zdt2(&x);
            assert!((v2[1] - (1.0 - f1 * f1)).abs() < 1e-12, "zdt2 front at {f1}");
        }
        // nonzero tail strictly worsens f2 at fixed f1
        let mut x = vec![0.5; 30];
        x[0] = 0.25;
        assert!(zdt1(&x)[1] > 1.0 - 0.25f64.sqrt());
    }

    #[test]
    fn dtlz2_front_is_unit_sphere_at_g_zero() {
        let mut rng = Pcg64::new(0);
        for _ in 0..50 {
            let mut x = vec![0.5; 12]; // tail at 0.5 ⇒ g = 0
            x[0] = rng.uniform();
            x[1] = rng.uniform();
            let v = dtlz2(&x);
            let norm: f64 = v.iter().map(|a| a * a).sum::<f64>();
            assert!((norm - 1.0).abs() < 1e-9, "‖f‖² = {norm}");
            assert!(v.iter().all(|&a| (-1e-12..=1.0 + 1e-12).contains(&a)));
        }
    }

    #[test]
    fn random_points_stay_inside_reference() {
        let mut rng = Pcg64::new(1);
        for f in moo_functions() {
            for _ in 0..300 {
                let x: Vec<f64> = f
                    .bounds
                    .iter()
                    .map(|(lo, hi)| rng.uniform_range(*lo, *hi))
                    .collect();
                let v = f.eval(&x);
                for (vi, ri) in v.iter().zip(&f.ref_point) {
                    assert!(vi < ri, "{}: objective {vi} >= reference {ri}", f.name);
                }
            }
        }
    }
}
