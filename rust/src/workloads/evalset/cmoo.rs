//! Constrained multi-objective workloads (ISSUE 8): the evalset MOO
//! protocol extended with inequality constraints `c(x) <= 0`. Each
//! problem's [`ConstrainedMooFunction::objective`] reports its
//! constraint vector through
//! [`crate::trial::TrialApi::report_constraints`], so a study running it
//! gets feasibility-aware fronts from [`crate::study::Study::best_trials`]
//! and feasibility-aware selection from constrained NSGA-II / TPE — with
//! no extra wiring in the runner (CLI, benches, tests all share this
//! single definition, like the unconstrained table).
//!
//! Two problems:
//!
//! * `czdt1` — ZDT1 with the unconstrained optimum forbidden:
//!   `c = 0.3 − f₁ <= 0` cuts off the `f₁ < 0.3` arm of the convex
//!   front, where blind optimizers concentrate. The feasible front is
//!   `f₂ = 1 − √f₁` on `f₁ ∈ [0.3, 1]`.
//! * `acclat` — an accuracy-vs-latency model-deployment sim under a
//!   memory cap: deeper/wider networks are more accurate but slower and
//!   bigger; quantization shrinks memory and latency at an accuracy
//!   cost. The cap makes the accurate corner infeasible unless
//!   quantized — the constraint actively bends the front.

use crate::core::OptunaError;
use crate::trial::{Trial, TrialApi};

/// One constrained multi-objective problem (objectives minimized,
/// constraints satisfied at `c <= 0`).
pub struct ConstrainedMooFunction {
    pub name: &'static str,
    pub dim: usize,
    pub n_obj: usize,
    pub n_cons: usize,
    /// (low, high) per dimension.
    pub bounds: Vec<(f64, f64)>,
    /// Hypervolume reference point (see [`super::MooFunction::ref_point`]).
    pub ref_point: Vec<f64>,
    /// `x -> (objectives, constraints)`.
    pub f: fn(&[f64]) -> (Vec<f64>, Vec<f64>),
}

impl ConstrainedMooFunction {
    /// Evaluate, asserting dimension and arities.
    pub fn eval(&self, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(x.len(), self.dim, "{}: wrong dimension", self.name);
        let (v, c) = (self.f)(x);
        debug_assert_eq!(v.len(), self.n_obj, "{}: wrong objective count", self.name);
        debug_assert_eq!(c.len(), self.n_cons, "{}: wrong constraint count", self.name);
        (v, c)
    }

    /// The shared study objective: suggest one `x<ii>` parameter per
    /// dimension, evaluate, report the constraint vector, return the
    /// objective vector (same naming scheme as the unconstrained table).
    pub fn objective(&self, t: &mut Trial<'_>) -> Result<Vec<f64>, OptunaError> {
        let x: Vec<f64> = self
            .bounds
            .iter()
            .enumerate()
            .map(|(i, (lo, hi))| t.suggest_float(&format!("x{i:02}"), *lo, *hi))
            .collect::<Result<_, _>>()?;
        let (values, constraints) = self.eval(&x);
        t.report_constraints(&constraints)?;
        Ok(values)
    }
}

/// ZDT1 (dim 8) with `f₁ >= 0.3` as the constraint `0.3 − f₁ <= 0`.
/// Dim 8 (not the classic 30) keeps the bench's fixed-budget studies
/// able to reach the front region at all.
pub fn czdt1(x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let v = super::moo::zdt1(x);
    let c = 0.3 - v[0];
    (v, vec![c])
}

/// Accuracy-vs-latency under a memory cap. Parameters:
/// `x0` = layers in [1, 12], `x1` = log₂ width in [4, 9]
/// (width 16..512), `x2` = quantization fraction in [0, 1].
///
/// * error (minimize): falls with capacity = layers·width, rises
///   mildly with quantization;
/// * latency (minimize): rises with layers and width, falls with
///   quantization;
/// * memory constraint: `layers·width·(1 − q/2)` must fit an 8 "MB"
///   cap (`c = mem/cap − 1 <= 0`) — the accurate corner only fits
///   when quantized.
pub fn acclat(x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let (layers, log_width, quant) = (x[0], x[1], x[2]);
    let width = log_width.exp2();
    let capacity = layers * width;
    let error = 0.02 + 1.6 / capacity.powf(0.4) + 0.08 * quant;
    let latency_ms = 0.05 * layers * width.powf(0.8) / (1.0 + 2.0 * quant);
    let mem_mb = layers * width * (1.0 - 0.5 * quant) / 256.0;
    let cap_mb = 8.0;
    (vec![error, latency_ms], vec![mem_mb / cap_mb - 1.0])
}

/// The constrained problem table (the shape of
/// [`super::moo_functions`], constraints added).
pub fn cmoo_functions() -> Vec<ConstrainedMooFunction> {
    vec![
        ConstrainedMooFunction {
            name: "czdt1",
            dim: 8,
            n_obj: 2,
            n_cons: 1,
            bounds: vec![(0.0, 1.0); 8],
            // f1 <= 1, f2 <= g <= 10 (same envelope as zdt1)
            ref_point: vec![1.1, 11.0],
            f: czdt1,
        },
        ConstrainedMooFunction {
            name: "acclat",
            dim: 3,
            n_obj: 2,
            n_cons: 1,
            bounds: vec![(1.0, 12.0), (4.0, 9.0), (0.0, 1.0)],
            // error <= 0.02 + 1.6/16^0.4 + 0.08 < 0.63; latency <=
            // 0.05·12·512^0.8 < 89
            ref_point: vec![0.8, 100.0],
            f: acclat,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn table_is_well_formed() {
        for f in cmoo_functions() {
            assert_eq!(f.bounds.len(), f.dim, "{}", f.name);
            assert_eq!(f.ref_point.len(), f.n_obj, "{}", f.name);
            let mid: Vec<f64> = f.bounds.iter().map(|(lo, hi)| 0.5 * (lo + hi)).collect();
            let (v, c) = f.eval(&mid);
            assert_eq!(v.len(), f.n_obj, "{}", f.name);
            assert_eq!(c.len(), f.n_cons, "{}", f.name);
            assert!(v.iter().chain(&c).all(|x| x.is_finite()), "{}: {v:?} {c:?}", f.name);
        }
    }

    #[test]
    fn czdt1_constraint_cuts_the_low_f1_arm() {
        // on the true front (tail = 0): f1 < 0.3 infeasible, f1 >= 0.3 feasible
        let at = |f1: f64| {
            let mut x = vec![0.0; 8];
            x[0] = f1;
            czdt1(&x)
        };
        let (v, c) = at(0.1);
        assert!(c[0] > 0.0, "f1=0.1 must violate");
        assert!((v[1] - (1.0 - 0.1f64.sqrt())).abs() < 1e-12);
        let (_, c) = at(0.3);
        assert!(c[0].abs() < 1e-12, "f1=0.3 is the boundary");
        let (_, c) = at(0.8);
        assert!(c[0] < 0.0, "f1=0.8 is feasible");
    }

    #[test]
    fn acclat_tradeoffs_point_the_right_way() {
        // more capacity: more accurate, slower, bigger
        let small = acclat(&[2.0, 5.0, 0.0]);
        let large = acclat(&[10.0, 8.0, 0.0]);
        assert!(large.0[0] < small.0[0], "bigger nets are more accurate");
        assert!(large.0[1] > small.0[1], "bigger nets are slower");
        assert!(large.1[0] > small.1[0], "bigger nets use more memory");
        // the big accurate corner violates the cap until quantized
        assert!(large.1[0] > 0.0, "10x256 must exceed the 8MB cap");
        let quantized = acclat(&[10.0, 8.0, 1.0]);
        assert!(quantized.1[0] < large.1[0]);
        assert!(quantized.0[1] < large.0[1], "quantization buys latency");
        assert!(quantized.0[0] > large.0[0], "quantization costs accuracy");
        // and the small corner is always feasible
        assert!(small.1[0] < 0.0);
    }

    #[test]
    fn random_points_stay_inside_reference() {
        let mut rng = Pcg64::new(3);
        for f in cmoo_functions() {
            for _ in 0..300 {
                let x: Vec<f64> = f
                    .bounds
                    .iter()
                    .map(|(lo, hi)| rng.uniform_range(*lo, *hi))
                    .collect();
                let (v, _) = f.eval(&x);
                for (vi, ri) in v.iter().zip(&f.ref_point) {
                    assert!(vi < ri, "{}: objective {vi} >= reference {ri}", f.name);
                }
            }
        }
    }

    #[test]
    fn feasible_region_is_reachable_by_random_search() {
        let mut rng = Pcg64::new(4);
        for f in cmoo_functions() {
            let mut feasible = 0;
            for _ in 0..200 {
                let x: Vec<f64> = f
                    .bounds
                    .iter()
                    .map(|(lo, hi)| rng.uniform_range(*lo, *hi))
                    .collect();
                let (_, c) = f.eval(&x);
                if c.iter().all(|&ci| ci <= 0.0) {
                    feasible += 1;
                }
            }
            assert!(
                feasible >= 20,
                "{}: only {feasible}/200 random points feasible — too tight to optimize",
                f.name
            );
        }
    }
}
