//! The black-box optimization test collection for Fig 9 / Fig 10.
//!
//! The paper evaluates samplers on the 56-function suite of
//! sigopt/evalset (McCourt 2016). That exact suite is a GitHub artifact;
//! per the substitution rule we ship 56 classic black-box functions of
//! the same families — unimodal bowls, multimodal landscapes, plateaus,
//! oscillatory and mixed-scale surfaces — with the evalset protocol
//! (fixed bounds per dimension, known optima where available).

mod functions;
pub mod cmoo;
pub mod moo;

pub use cmoo::{cmoo_functions, ConstrainedMooFunction};
pub use functions::all_functions;
pub use moo::{moo_functions, MooFunction};

/// One benchmark problem.
pub struct TestFunction {
    pub name: &'static str,
    pub dim: usize,
    /// (low, high) per dimension.
    pub bounds: Vec<(f64, f64)>,
    /// Known/approximate global minimum value.
    pub fmin: f64,
    /// A global minimizer, when known exactly enough to test against.
    pub argmin: Option<Vec<f64>>,
    pub f: fn(&[f64]) -> f64,
}

impl TestFunction {
    /// Evaluate, asserting dimension.
    pub fn eval(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.dim, "{}: wrong dimension", self.name);
        (self.f)(x)
    }

    /// Uniform bounds helper used by the function table.
    pub(crate) fn cube(
        name: &'static str,
        dim: usize,
        low: f64,
        high: f64,
        fmin: f64,
        argmin: Option<Vec<f64>>,
        f: fn(&[f64]) -> f64,
    ) -> TestFunction {
        TestFunction { name, dim, bounds: vec![(low, high); dim], fmin, argmin, f }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn exactly_56_functions_unique_names() {
        let fns = all_functions();
        assert_eq!(fns.len(), 56);
        let mut names: Vec<&str> = fns.iter().map(|f| f.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 56);
    }

    #[test]
    fn bounds_match_dim_and_are_ordered() {
        for f in all_functions() {
            assert_eq!(f.bounds.len(), f.dim, "{}", f.name);
            for (lo, hi) in &f.bounds {
                assert!(lo < hi, "{}: bounds ({lo}, {hi})", f.name);
            }
        }
    }

    #[test]
    fn argmin_attains_fmin() {
        for f in all_functions() {
            if let Some(xstar) = &f.argmin {
                let v = f.eval(xstar);
                let tol = 1e-3 * (1.0 + f.fmin.abs());
                assert!(
                    (v - f.fmin).abs() < tol,
                    "{}: f(argmin)={v}, fmin={}",
                    f.name,
                    f.fmin
                );
                // argmin must lie inside the bounds
                for (xi, (lo, hi)) in xstar.iter().zip(&f.bounds) {
                    assert!(xi >= lo && xi <= hi, "{}: argmin outside bounds", f.name);
                }
            }
        }
    }

    #[test]
    fn random_points_never_beat_fmin() {
        let mut rng = Pcg64::new(0);
        for f in all_functions() {
            for _ in 0..300 {
                let x: Vec<f64> = f
                    .bounds
                    .iter()
                    .map(|(lo, hi)| rng.uniform_range(*lo, *hi))
                    .collect();
                let v = f.eval(&x);
                assert!(v.is_finite(), "{}: non-finite at {x:?}", f.name);
                let tol = 1e-6 * (1.0 + f.fmin.abs());
                assert!(
                    v >= f.fmin - tol,
                    "{}: f({x:?}) = {v} beats fmin {}",
                    f.name,
                    f.fmin
                );
            }
        }
    }

    #[test]
    fn functions_are_not_constant() {
        let mut rng = Pcg64::new(1);
        for f in all_functions() {
            let sample = |rng: &mut Pcg64| -> f64 {
                let x: Vec<f64> = f
                    .bounds
                    .iter()
                    .map(|(lo, hi)| rng.uniform_range(*lo, *hi))
                    .collect();
                f.eval(&x)
            };
            let a = sample(&mut rng);
            let mut differs = false;
            for _ in 0..20 {
                if (sample(&mut rng) - a).abs() > 1e-12 {
                    differs = true;
                    break;
                }
            }
            // needle-in-haystack functions (easom) are flat almost
            // everywhere; the argmin still differs from the plateau
            if !differs {
                if let Some(xstar) = &f.argmin {
                    differs = (f.eval(xstar) - a).abs() > 1e-6;
                }
            }
            assert!(differs, "{} looks constant", f.name);
        }
    }

    #[test]
    fn dimensions_span_protocol_range() {
        let fns = all_functions();
        let max_dim = fns.iter().map(|f| f.dim).max().unwrap();
        let n2 = fns.iter().filter(|f| f.dim == 2).count();
        assert!(max_dim >= 8, "suite should include >10-variable cases: {max_dim}");
        assert!(n2 >= 20, "suite should be rich in 2-d cases: {n2}");
    }
}
