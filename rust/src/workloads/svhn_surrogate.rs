//! Learning-curve surrogate of the §5.2 experiment: simplified AlexNet on
//! SVHN, 8 hyperparameters, reported test error per training step.
//!
//! Running the paper's 40 repeats × 4 GPU-hours is out of scope for this
//! testbed; the pruning/distributed results (Fig 11a-c, Fig 12) depend
//! only on (a) the *shape* of learning curves as a function of
//! hyperparameters and (b) the per-step wallclock cost — both of which
//! this surrogate reproduces deterministically from a seed. The real
//! JAX-model path (mlmodel::TrainSession via PJRT) is exercised by
//! examples/e2e_mlp_svhn.rs; this module is the scale path.
//!
//! Curve model:  err(t) = floor + (0.9 − floor)·exp(−t/τ) + ε_t
//! with floor/τ structured functions of the 8 hyperparameters (steeper lr
//! penalty above the stability limit, capacity saturation, dropout sweet
//! spot) and ε_t small seeded noise. Virtual step cost scales with model
//! capacity so that a full no-pruning trial averages ≈400 s — matching the
//! paper's ~36 trials per 4-hour study.

use crate::core::OptunaError;
use crate::trial::TrialApi;
use crate::util::rng::Pcg64;

/// Steps per full trial (each step reports once; ASHA rungs at 1,4,16,64).
pub const MAX_STEPS: u64 = 64;

/// The 8 tunable hyperparameters (paper count).
#[derive(Debug, Clone)]
pub struct SurrogateParams {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub dropout: f64,
    pub c1: i64,
    pub c2: i64,
    pub c3: i64,
    pub fc: i64,
}

/// Suggest the 8-hyperparameter space through the define-by-run API.
pub fn suggest_params<T: TrialApi>(t: &mut T) -> Result<SurrogateParams, OptunaError> {
    Ok(SurrogateParams {
        lr: t.suggest_float_log("lr", 1e-4, 1.0)?,
        momentum: t.suggest_float("momentum", 0.5, 0.999)?,
        weight_decay: t.suggest_float_log("weight_decay", 1e-6, 1e-2)?,
        dropout: t.suggest_float("dropout", 0.0, 0.7)?,
        c1: t.suggest_int_log("c1", 8, 64)?,
        c2: t.suggest_int_log("c2", 16, 128)?,
        c3: t.suggest_int_log("c3", 16, 128)?,
        fc: t.suggest_int_log("fc", 32, 512)?,
    })
}

/// A deterministic learning curve + cost model for one trial.
pub struct TrialCurve {
    pub floor: f64,
    pub tau: f64,
    /// Seconds of simulated wallclock per training step.
    pub step_seconds: f64,
    noise: Pcg64,
    noise_amp: f64,
    cached_step: u64,
    cached_err: f64,
}

impl SurrogateParams {
    /// Capacity proxy: log2 of the parameter-count-ish product.
    fn capacity(&self) -> f64 {
        ((self.c1 * self.c2 * self.c3 * self.fc) as f64).log2()
    }

    /// Asymptotic test error as a structured function of the hyperparams.
    pub fn error_floor(&self) -> f64 {
        let log_lr = self.lr.log10(); // in [-4, 0]
        // sweet spot near lr = 10^-1.5; divergence above ~10^-0.5
        let lr_pen = if log_lr > -0.5 {
            0.55 + 0.3 * (log_lr + 0.5)
        } else {
            0.045 * (log_lr + 1.5) * (log_lr + 1.5)
        };
        let mom_pen = 0.35 * (self.momentum - 0.9).abs();
        let wd_pen = 0.015 * (self.weight_decay.log10() + 4.0).abs();
        let do_pen = 0.25 * (self.dropout - 0.2) * (self.dropout - 0.2);
        // capacity saturates: cap ranges ~[16, 26]
        let cap_pen = 0.5 * (-(self.capacity() - 16.0) / 4.0).exp();
        (0.075 + lr_pen + mom_pen + wd_pen + do_pen + cap_pen).clamp(0.05, 0.95)
    }

    /// Convergence time constant in steps.
    pub fn time_constant(&self) -> f64 {
        let lr_slow = (0.03 / self.lr).powf(0.25).clamp(0.4, 4.0);
        let cap_slow = (self.capacity() / 20.0).clamp(0.7, 1.6);
        6.0 * lr_slow * cap_slow
    }

    /// Simulated seconds per training step (compute scales with capacity).
    pub fn step_seconds(&self) -> f64 {
        // full trial (64 steps) ≈ 250–700 s depending on width; mid ≈ 400 s
        let rel = (self.capacity() - 16.0) / 10.0; // ~[0,1]
        3.2 + 6.0 * rel.clamp(0.0, 1.2)
    }

    /// Build the deterministic curve for this trial.
    pub fn curve(&self, noise_seed: u64) -> TrialCurve {
        TrialCurve {
            floor: self.error_floor(),
            tau: self.time_constant(),
            step_seconds: self.step_seconds(),
            noise: Pcg64::new(noise_seed),
            noise_amp: 0.008,
            cached_step: 0,
            cached_err: 0.9,
        }
    }
}

impl TrialCurve {
    /// Test error after `step` training steps (steps are consumed in
    /// order; the noise stream makes curves wiggle realistically).
    pub fn err_at(&mut self, step: u64) -> f64 {
        assert!(step >= 1, "steps are 1-based");
        assert!(step > self.cached_step, "curve must be advanced monotonically");
        while self.cached_step < step {
            self.cached_step += 1;
            let t = self.cached_step as f64;
            let mean = self.floor + (0.9 - self.floor) * (-t / self.tau).exp();
            let eps = self.noise_amp * self.noise.normal();
            self.cached_err = (mean + eps).clamp(0.01, 1.0);
        }
        self.cached_err
    }

    /// Final error of a fully-trained trial.
    pub fn final_err(&mut self) -> f64 {
        self.err_at(MAX_STEPS.max(self.cached_step + 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn good() -> SurrogateParams {
        SurrogateParams {
            lr: 0.03,
            momentum: 0.9,
            weight_decay: 1e-4,
            dropout: 0.2,
            c1: 48,
            c2: 96,
            c3: 96,
            fc: 384,
        }
    }

    fn bad() -> SurrogateParams {
        SurrogateParams {
            lr: 0.9, // above stability limit
            momentum: 0.5,
            weight_decay: 1e-6,
            dropout: 0.7,
            c1: 8,
            c2: 16,
            c3: 16,
            fc: 32,
        }
    }

    #[test]
    fn good_config_beats_bad_config() {
        let g = good().error_floor();
        let b = bad().error_floor();
        assert!(g < 0.15, "good floor {g}");
        assert!(b > 0.6, "bad floor {b}");
    }

    #[test]
    fn curves_decrease_toward_floor() {
        let mut c = good().curve(0);
        let early = c.err_at(1);
        let late = c.err_at(MAX_STEPS);
        assert!(late < early, "{early} -> {late}");
        assert!((late - good().error_floor()).abs() < 0.05);
    }

    #[test]
    fn curves_are_deterministic_per_seed() {
        let mut a = good().curve(7);
        let mut b = good().curve(7);
        for s in 1..=10 {
            assert_eq!(a.err_at(s), b.err_at(s));
        }
        let mut cdiff = good().curve(8);
        let mut any = false;
        let mut a2 = good().curve(7);
        for s in 1..=10 {
            if cdiff.err_at(s) != a2.err_at(s) {
                any = true;
            }
        }
        assert!(any, "different seeds must differ");
    }

    #[test]
    fn full_trial_costs_about_400_seconds() {
        // mid-capacity config ≈ paper's 4h / 36 trials ≈ 400 s
        let p = SurrogateParams { c1: 24, c2: 48, c3: 48, fc: 128, ..good() };
        let total = p.step_seconds() * MAX_STEPS as f64;
        assert!((250.0..700.0).contains(&total), "total={total}");
    }

    #[test]
    fn step_cost_grows_with_capacity() {
        let small = SurrogateParams { c1: 8, c2: 16, c3: 16, fc: 32, ..good() };
        let large = SurrogateParams { c1: 64, c2: 128, c3: 128, fc: 512, ..good() };
        assert!(large.step_seconds() > small.step_seconds());
    }

    #[test]
    fn suggest_params_roundtrip_through_study() {
        use crate::prelude::*;
        use std::sync::Arc;
        let study = Study::builder()
            .name("surrogate")
            .sampler(Arc::new(RandomSampler::new(0)))
            .build()
            .unwrap();
        study
            .optimize(10, |t| {
                let p = suggest_params(t)?;
                let mut curve = p.curve(t.number());
                Ok(curve.final_err())
            })
            .unwrap();
        assert_eq!(study.trials().unwrap().len(), 10);
        let best = study.best_value().unwrap().unwrap();
        assert!((0.0..1.0).contains(&best));
    }

    #[test]
    #[should_panic(expected = "monotonically")]
    fn curve_rejects_rewind() {
        let mut c = good().curve(0);
        c.err_at(5);
        c.err_at(3);
    }
}
