//! FFmpeg encoder tuning workload (§6): a rate–distortion model of an
//! x264-style encoder on a Big-Buck-Bunny-like clip.
//!
//! The paper tunes encoder parameters to minimize reconstruction error
//! and reports that the found configuration is on par with the second
//! best of the developer presets. The model assigns each encoder tool a
//! diminishing-returns quality contribution and a speed cost, calibrated
//! so the provided presets are correctly ordered (faster presets ⇒ higher
//! distortion at the fixed bitrate budget).

use crate::core::OptunaError;
use crate::trial::TrialApi;

/// One encoder configuration.
#[derive(Debug, Clone)]
pub struct EncoderConfig {
    pub subme: i64,        // subpixel ME refinement 0..=10
    pub me_range: i64,     // motion search range 4..=64
    pub refs: i64,         // reference frames 1..=16
    pub bframes: i64,      // consecutive B-frames 0..=8
    pub trellis: i64,      // 0..=2
    pub aq_strength: f64,  // adaptive quantization 0..=2
    pub psy_rd: f64,       // psychovisual RD 0..=2
    pub mixed_refs: bool,
    pub me_method: String, // dia/hex/umh/esa
    pub rc_lookahead: i64, // 10..=60
}

/// The developer presets (ultrafast → veryslow), as in x264.
pub fn presets() -> Vec<(&'static str, EncoderConfig)> {
    let mk = |subme, me_range, refs, bframes, trellis, aq, psy, mixed, me: &'static str, la| EncoderConfig {
        subme,
        me_range,
        refs,
        bframes,
        trellis,
        aq_strength: aq,
        psy_rd: psy,
        mixed_refs: mixed,
        me_method: me.to_string(),
        rc_lookahead: la,
    };
    vec![
        ("ultrafast", mk(0, 4, 1, 0, 0, 0.0, 0.0, false, "dia", 10)),
        ("superfast", mk(1, 8, 1, 0, 0, 0.6, 0.4, false, "dia", 10)),
        ("veryfast", mk(2, 16, 1, 3, 0, 0.8, 0.6, false, "hex", 10)),
        ("faster", mk(4, 16, 2, 3, 1, 1.0, 0.8, false, "hex", 20)),
        ("fast", mk(6, 16, 2, 3, 1, 1.0, 1.0, false, "hex", 30)),
        ("medium", mk(7, 16, 3, 3, 1, 1.0, 1.0, true, "hex", 40)),
        ("slow", mk(8, 16, 5, 3, 2, 1.0, 1.0, true, "umh", 50)),
        ("slower", mk(9, 24, 8, 3, 2, 1.0, 1.0, true, "umh", 60)),
        ("veryslow", mk(10, 24, 16, 8, 2, 1.0, 1.0, true, "umh", 60)),
    ]
}

/// Suggest the encoder space through the define-by-run API.
pub fn suggest_config<T: TrialApi>(t: &mut T) -> Result<EncoderConfig, OptunaError> {
    Ok(EncoderConfig {
        subme: t.suggest_int("subme", 0, 10)?,
        me_range: t.suggest_int("me_range", 4, 64)?,
        refs: t.suggest_int_log("refs", 1, 16)?,
        bframes: t.suggest_int("bframes", 0, 8)?,
        trellis: t.suggest_int("trellis", 0, 2)?,
        aq_strength: t.suggest_float("aq_strength", 0.0, 2.0)?,
        psy_rd: t.suggest_float("psy_rd", 0.0, 2.0)?,
        mixed_refs: t.suggest_categorical("mixed_refs", &["false", "true"])? == "true",
        me_method: t.suggest_categorical("me_method", &["dia", "hex", "umh", "esa"])?,
        rc_lookahead: t.suggest_int("rc_lookahead", 10, 60)?,
    })
}

impl EncoderConfig {
    /// Reconstruction error (lower = better) at the fixed bitrate budget.
    /// Modeled as a base distortion minus diminishing-returns gains per
    /// tool, plus penalties for mis-set psychovisual knobs.
    pub fn distortion(&self) -> f64 {
        let gain_subme = 0.030 * (1.0 - (-(self.subme as f64) / 3.0).exp());
        let gain_refs = 0.016 * (1.0 - (-((self.refs - 1) as f64) / 3.0).exp());
        let gain_bf = 0.012 * (1.0 - (-(self.bframes as f64) / 2.0).exp());
        let gain_trellis = 0.006 * self.trellis as f64 / 2.0;
        let gain_me = match self.me_method.as_str() {
            "dia" => 0.0,
            "hex" => 0.004,
            "umh" => 0.007,
            _ => 0.008, // esa: marginal over umh
        };
        let gain_range = 0.005 * ((self.me_range as f64 / 16.0).min(2.0) - 0.25).max(0.0) / 1.75;
        let gain_mixed = if self.mixed_refs { 0.003 } else { 0.0 };
        let gain_la = 0.008 * (1.0 - (-((self.rc_lookahead - 10) as f64) / 20.0).exp());
        // aq/psy have sweet spots near 1.0
        let pen_aq = 0.006 * (self.aq_strength - 1.0) * (self.aq_strength - 1.0);
        let pen_psy = 0.005 * (self.psy_rd - 1.0) * (self.psy_rd - 1.0);
        let base = 0.120;
        (base - gain_subme - gain_refs - gain_bf - gain_trellis - gain_me - gain_range
            - gain_mixed
            - gain_la
            + pen_aq
            + pen_psy)
            .max(0.02)
    }

    /// Encode wallclock in simulated seconds (pruning/time accounting).
    pub fn encode_seconds(&self) -> f64 {
        let me_cost = match self.me_method.as_str() {
            "dia" => 1.0,
            "hex" => 1.3,
            "umh" => 2.2,
            _ => 6.0, // esa exhaustive
        };
        30.0 * (1.0 + 0.25 * self.subme as f64)
            * (1.0 + 0.08 * self.refs as f64)
            * (1.0 + 0.05 * self.bframes as f64)
            * me_cost
            * (1.0 + 0.004 * self.me_range as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_ordered_by_quality() {
        let ps = presets();
        let d: Vec<f64> = ps.iter().map(|(_, c)| c.distortion()).collect();
        for w in d.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "preset order violated: {d:?}");
        }
        // and slower presets cost more time
        let t: Vec<f64> = ps.iter().map(|(_, c)| c.encode_seconds()).collect();
        assert!(t.last().unwrap() > t.first().unwrap());
    }

    #[test]
    fn tuned_study_matches_second_best_preset() {
        use crate::prelude::*;
        use std::sync::Arc;
        let study = Study::builder()
            .name("ffmpeg")
            .sampler(Arc::new(TpeSampler::new(0)))
            .build()
            .unwrap();
        study
            .optimize(150, |t| {
                let cfg = suggest_config(t)?;
                Ok(cfg.distortion())
            })
            .unwrap();
        let best = study.best_value().unwrap().unwrap();
        let ps = presets();
        let second_best = ps[ps.len() - 2].1.distortion();
        // paper: "on par with the second best parameter-set among presets"
        assert!(
            best <= second_best * 1.05,
            "best={best}, second_best={second_best}"
        );
    }

    #[test]
    fn distortion_positive_and_bounded() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(0);
        for _ in 0..200 {
            let c = EncoderConfig {
                subme: rng.int_range(0, 10),
                me_range: rng.int_range(4, 64),
                refs: rng.int_range(1, 16),
                bframes: rng.int_range(0, 8),
                trellis: rng.int_range(0, 2),
                aq_strength: rng.uniform_range(0.0, 2.0),
                psy_rd: rng.uniform_range(0.0, 2.0),
                mixed_refs: rng.uniform() < 0.5,
                me_method: ["dia", "hex", "umh", "esa"][rng.index(4)].to_string(),
                rc_lookahead: rng.int_range(10, 60),
            };
            let d = c.distortion();
            assert!((0.0..0.2).contains(&d));
            assert!(c.encode_seconds() > 0.0);
        }
    }
}
