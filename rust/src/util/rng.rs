//! Deterministic PRNG: PCG64 (O'Neill 2014, XSL-RR 128/64 variant).
//!
//! The offline build has no `rand` crate, so the framework carries its own
//! generator. Every stochastic component (samplers, workload simulators,
//! synthetic data) takes an explicit seed so studies replay exactly.

/// PCG64 XSL-RR: 128-bit LCG state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second output of Box-Muller.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an arbitrary u64; stream constant fixed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for e.g. per-worker generators.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            gauss_spare: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [low, high).
    pub fn uniform_range(&mut self, low: f64, high: f64) -> f64 {
        low + (high - low) * self.uniform()
    }

    /// Uniform integer in [low, high] inclusive (unbiased via rejection).
    pub fn int_range(&mut self, low: i64, high: i64) -> i64 {
        debug_assert!(low <= high);
        let span = (high - low) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return low + (v % span) as i64;
            }
        }
    }

    /// Index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.int_range(0, n as i64 - 1) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Normal truncated to [low, high] by rejection (falls back to uniform
    /// after 100 rejections — only reachable for pathological bounds).
    pub fn trunc_normal(&mut self, mean: f64, std: f64, low: f64, high: f64) -> f64 {
        debug_assert!(low < high);
        for _ in 0..100 {
            let v = self.normal_ms(mean, std);
            if v >= low && v <= high {
                return v;
            }
        }
        self.uniform_range(low, high)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from unnormalized weights. See [`Self::try_weighted_index`]
    /// for the degenerate-input contract; panics only when *no* index
    /// carries a usable (finite, non-negative) weight.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        self.try_weighted_index(weights)
            .expect("weighted_index: no finite non-negative weight to sample from")
    }

    /// Sample an index with probability proportional to its weight.
    ///
    /// The old implementation summed blindly and walked `u -= w`, so a
    /// NaN weight poisoned `u` (the `u <= 0.0` test never fires) and an
    /// all-zero vector fell through — both silently returned the *last*
    /// index, the `debug_assert!(total > 0.0)` being stripped in release.
    /// Now:
    ///
    /// * non-finite and negative weights are skipped entirely (a NaN or
    ///   −1 weight can never be returned);
    /// * if no positive mass remains (all-zero vector, or a sum that
    ///   overflows to +∞), the pick is uniform over the indices that at
    ///   least carried a valid `>= 0` finite weight;
    /// * with nothing valid at all, [`NoValidWeights`] — the caller
    ///   decides, instead of receiving a silently-biased index.
    ///
    /// For well-formed inputs (all weights finite and positive) this is
    /// the historical fast path bit for bit: one [`Self::uniform`] draw,
    /// the same subtraction walk, the same result — the determinism
    /// suites pin the RNG stream.
    pub fn try_weighted_index(&mut self, weights: &[f64]) -> Result<usize, NoValidWeights> {
        let total: f64 = weights
            .iter()
            .filter(|w| w.is_finite() && **w > 0.0)
            .sum();
        if total > 0.0 && total.is_finite() {
            let mut u = self.uniform() * total;
            let mut last_positive = 0usize;
            for (i, &w) in weights.iter().enumerate() {
                if !(w.is_finite() && w > 0.0) {
                    continue;
                }
                u -= w;
                if u <= 0.0 {
                    return Ok(i);
                }
                last_positive = i;
            }
            // float residue: land on the last *positive* index, never on
            // a trailing zero/NaN like the old code did
            return Ok(last_positive);
        }
        let n_valid = weights.iter().filter(|w| w.is_finite() && **w >= 0.0).count();
        if n_valid == 0 {
            return Err(NoValidWeights);
        }
        let mut pick = self.index(n_valid);
        for (i, &w) in weights.iter().enumerate() {
            if w.is_finite() && w >= 0.0 {
                if pick == 0 {
                    return Ok(i);
                }
                pick -= 1;
            }
        }
        unreachable!("valid-weight count changed mid-scan")
    }

    /// Fresh generator split off this one (for child tasks).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::with_stream(seed, stream)
    }
}

/// Error from [`Pcg64::try_weighted_index`]: every weight was NaN,
/// infinite, or negative — there is no defensible index to return.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoValidWeights;

impl std::fmt::Display for NoValidWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "weighted sampling: no finite non-negative weight in the vector")
    }
}

impl std::error::Error for NoValidWeights {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_variance() {
        let mut r = Pcg64::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn int_range_covers_and_bounds() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.int_range(10, 14);
            assert!((10..=14).contains(&v));
            seen[(v - 10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn trunc_normal_respects_bounds() {
        let mut r = Pcg64::new(13);
        for _ in 0..5000 {
            let v = r.trunc_normal(0.0, 10.0, -1.0, 1.0);
            assert!((-1.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Pcg64::new(17);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > 5 * counts[1] / 2);
    }

    #[test]
    fn weighted_index_all_zero_falls_back_to_uniform() {
        // regression: the old walk never fired `u <= 0` here and always
        // returned the last index
        let mut r = Pcg64::new(21);
        let w = [0.0, 0.0, 0.0];
        let mut counts = [0usize; 3];
        for _ in 0..6000 {
            counts[r.weighted_index(&w)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 1500, "index {i} under uniform fallback: {counts:?}");
        }
    }

    #[test]
    fn weighted_index_skips_nan_weight() {
        // regression: a single NaN used to poison u and select the last
        // index unconditionally
        let mut r = Pcg64::new(23);
        let w = [1.0, f64::NAN, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..6000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0, "NaN-weighted index was sampled: {counts:?}");
        assert!(counts[0] > 2000 && counts[2] > 2000, "bias: {counts:?}");
    }

    #[test]
    fn weighted_index_never_returns_trailing_zero() {
        // regression: float residue in the subtraction walk used to land
        // on the last index even when its weight was zero
        let mut r = Pcg64::new(25);
        let w = [1.0, 1.0, 0.0];
        for _ in 0..10_000 {
            assert_ne!(r.weighted_index(&w), 2);
        }
    }

    #[test]
    fn weighted_index_all_invalid_is_typed_error() {
        let mut r = Pcg64::new(27);
        assert_eq!(
            r.try_weighted_index(&[f64::NAN, -1.0, f64::INFINITY]),
            Err(NoValidWeights)
        );
        assert_eq!(r.try_weighted_index(&[]), Err(NoValidWeights));
    }

    #[test]
    fn weighted_index_valid_path_consumes_one_uniform_and_is_unchanged() {
        // all-positive vectors must keep the historical draw discipline
        // exactly — the determinism suites pin the RNG stream
        let mut a = Pcg64::new(29);
        let mut b = Pcg64::new(29);
        let w = [2.0, 1.0, 5.0];
        let i = a.weighted_index(&w);
        let mut u = b.uniform() * (2.0 + 1.0 + 5.0);
        let mut want = w.len() - 1;
        for (k, &x) in w.iter().enumerate() {
            u -= x;
            if u <= 0.0 {
                want = k;
                break;
            }
        }
        assert_eq!(i, want, "selection diverged from the historical walk");
        assert_eq!(a.next_u64(), b.next_u64(), "RNG stream advanced differently");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Pcg64::new(23);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let a: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
