//! Statistics for the evaluation protocol and the TPE/GP samplers.
//!
//! Fig 9 of the paper compares samplers with a paired Mann-Whitney U test
//! at α = 0.0005 over 30 repeated studies; this module provides that test
//! (both the classic unpaired U and the paired Wilcoxon signed-rank the
//! "paired Mann-Whitney" phrasing refers to), midrank utilities, the
//! standard normal CDF/quantile, and descriptive statistics.

use std::cmp::Ordering;

/// Total order on `f64` with every NaN treated as the greatest value
/// (and all NaNs equal, regardless of sign/payload bits).
///
/// This is the one comparator the framework sorts objective values with:
/// a diverged trial tell'd with `NaN` lands at the "worst" end of a
/// minimization ranking instead of panicking the
/// `partial_cmp(..).unwrap()` the samplers and pruners used to call.
/// For NaN-free inputs it orders exactly like `partial_cmp`.
#[inline]
pub fn nan_max_cmp(a: &f64, b: &f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.partial_cmp(b).unwrap(),
    }
}

/// Arithmetic mean; NaN for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1); 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Median via partial selection — O(n) expected instead of the former
/// copy-and-full-sort, which dominated `MedianPruner` decisions on the
/// non-indexed path. NaN-safe per [`nan_max_cmp`]; NaN for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    let n = v.len();
    let (below, mid, _) = v.select_nth_unstable_by(n / 2, nan_max_cmp);
    if n % 2 == 1 {
        *mid
    } else {
        // the n/2-1 ranked element is the max of the left partition
        let lower = below
            .iter()
            .copied()
            .max_by(nan_max_cmp)
            .expect("even n >= 2 has a non-empty left partition");
        0.5 * (lower + *mid)
    }
}

/// p-quantile with linear interpolation, p in [0,1]. NaN-safe per
/// [`nan_max_cmp`].
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_unstable_by(nan_max_cmp);
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

/// Midranks (1-based, ties averaged) — the ranking used by U and W tests.
pub fn midranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Standard normal CDF via erf (Abramowitz–Stegun 7.1.26 rational approx,
/// |err| < 1.5e-7 — ample for test decisions at α = 5e-4).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * erfc(-z / std::f64::consts::SQRT_2)
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    let ax = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * ax);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let e = poly * (-ax * ax).exp();
    if x >= 0.0 {
        e
    } else {
        2.0 - e
    }
}

/// erf(x) = 1 − erfc(x).
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// One-sided Mann-Whitney U test that `xs` tends SMALLER than `ys`
/// (H1: P(X < Y) > 1/2). Returns (U statistic of xs, one-sided p-value)
/// using the normal approximation with tie correction.
pub fn mann_whitney_u_less(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let n1 = xs.len() as f64;
    let n2 = ys.len() as f64;
    assert!(n1 > 0.0 && n2 > 0.0);
    let mut all: Vec<f64> = Vec::with_capacity(xs.len() + ys.len());
    all.extend_from_slice(xs);
    all.extend_from_slice(ys);
    let ranks = midranks(&all);
    let r1: f64 = ranks[..xs.len()].iter().sum();
    let u1 = r1 - n1 * (n1 + 1.0) / 2.0; // "big when xs big"
    let mu = n1 * n2 / 2.0;
    // tie correction
    let mut sorted = all.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = n1 + n2;
    let mut tie_term = 0.0;
    let mut i = 0;
    while i < sorted.len() {
        let mut j = i;
        while j + 1 < sorted.len() && sorted[j + 1] == sorted[i] {
            j += 1;
        }
        let t = (j - i + 1) as f64;
        tie_term += t * t * t - t;
        i = j + 1;
    }
    let sigma2 = n1 * n2 / 12.0 * ((n + 1.0) - tie_term / (n * (n - 1.0)));
    if sigma2 <= 0.0 {
        return (u1, 0.5); // all values identical
    }
    // H1 "xs smaller" => u1 small => z negative
    let z = (u1 - mu + 0.5) / sigma2.sqrt(); // continuity correction toward H1
    (u1, normal_cdf(z))
}

/// Paired one-sided Wilcoxon signed-rank test that paired differences
/// d = x − y tend NEGATIVE (xs smaller), i.e. the "paired Mann-Whitney"
/// protocol of Fig 9. Returns (W+, one-sided p) by normal approximation;
/// zero differences dropped (Wilcoxon's method).
pub fn wilcoxon_signed_rank_less(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let diffs: Vec<f64> = xs
        .iter()
        .zip(ys)
        .map(|(a, b)| a - b)
        .filter(|d| *d != 0.0)
        .collect();
    let n = diffs.len();
    if n == 0 {
        return (0.0, 0.5);
    }
    let abs: Vec<f64> = diffs.iter().map(|d| d.abs()).collect();
    let ranks = midranks(&abs);
    let w_plus: f64 = diffs
        .iter()
        .zip(&ranks)
        .filter(|(d, _)| **d > 0.0)
        .map(|(_, r)| *r)
        .sum();
    let nf = n as f64;
    let mu = nf * (nf + 1.0) / 4.0;
    // tie correction over |d| ranks
    let mut sorted = abs.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut tie_term = 0.0;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && sorted[j + 1] == sorted[i] {
            j += 1;
        }
        let t = (j - i + 1) as f64;
        tie_term += t * t * t - t;
        i = j + 1;
    }
    let sigma2 = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0 - tie_term / 48.0;
    if sigma2 <= 0.0 {
        return (w_plus, 0.5);
    }
    // H1 "x < y" => diffs negative => W+ small
    let z = (w_plus - mu + 0.5) / sigma2.sqrt();
    (w_plus, normal_cdf(z))
}

/// Outcome of the Fig 9 three-way comparison at significance `alpha`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Comparison {
    /// First sampler statistically better (smaller losses).
    Win,
    /// Second sampler statistically better.
    Loss,
    /// Neither direction significant.
    Tie,
}

/// Paired comparison of best-values across repeated studies (lower=better),
/// per the Fig 9 protocol.
pub fn compare_paired(a: &[f64], b: &[f64], alpha: f64) -> Comparison {
    let (_, p_a_less) = wilcoxon_signed_rank_less(a, b);
    let (_, p_b_less) = wilcoxon_signed_rank_less(b, a);
    if p_a_less < alpha {
        Comparison::Win
    } else if p_b_less < alpha {
        Comparison::Loss
    } else {
        Comparison::Tie
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn descriptive_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((std_dev(&xs) - 1.2909944487).abs() < 1e-9);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn median_matches_full_sort_reference() {
        let mut rng = Pcg64::new(11);
        for n in 1..40usize {
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform_range(-3.0, 3.0)).collect();
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let reference = if n % 2 == 1 {
                sorted[n / 2]
            } else {
                0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
            };
            assert_eq!(median(&xs), reference, "n={n}");
        }
    }

    #[test]
    fn nan_sorts_greatest_not_panics() {
        // a NaN entry must not panic and must rank as the worst value
        assert_eq!(median(&[1.0, f64::NAN, 3.0]), 3.0);
        assert_eq!(quantile(&[1.0, 2.0, f64::NAN], 0.0), 1.0);
        assert_eq!(nan_max_cmp(&f64::NAN, &f64::INFINITY), Ordering::Greater);
        assert_eq!(nan_max_cmp(&-f64::NAN, &f64::NAN), Ordering::Equal);
        assert_eq!(nan_max_cmp(&1.0, &2.0), Ordering::Less);
    }

    #[test]
    fn midranks_with_ties() {
        let r = midranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn normal_cdf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-3.29) - 0.0005).abs() < 2e-4);
        assert!(normal_cdf(8.0) > 0.999999);
    }

    #[test]
    fn mann_whitney_detects_shift() {
        let mut rng = Pcg64::new(5);
        let xs: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..40).map(|_| rng.normal() + 2.0).collect();
        let (_, p) = mann_whitney_u_less(&xs, &ys);
        assert!(p < 1e-4, "p={p}");
        let (_, p_rev) = mann_whitney_u_less(&ys, &xs);
        assert!(p_rev > 0.5, "p_rev={p_rev}");
    }

    #[test]
    fn mann_whitney_null_uniform() {
        let mut rng = Pcg64::new(6);
        let xs: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let (_, p) = mann_whitney_u_less(&xs, &ys);
        assert!(p > 0.001 && p < 0.999, "p={p}");
    }

    #[test]
    fn wilcoxon_detects_paired_shift() {
        let mut rng = Pcg64::new(7);
        let base: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let xs: Vec<f64> = base.iter().map(|b| b - 1.0 + 0.1 * rng.normal()).collect();
        let ys = base;
        let (_, p) = wilcoxon_signed_rank_less(&xs, &ys);
        assert!(p < 5e-4, "p={p}");
    }

    #[test]
    fn wilcoxon_all_equal_is_tie() {
        let xs = vec![1.0; 10];
        let (_, p) = wilcoxon_signed_rank_less(&xs, &xs);
        assert_eq!(p, 0.5);
    }

    #[test]
    fn compare_paired_three_outcomes() {
        let mut rng = Pcg64::new(8);
        let base: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let better: Vec<f64> = base.iter().map(|b| b - 2.0).collect();
        assert_eq!(compare_paired(&better, &base, 5e-4), Comparison::Win);
        assert_eq!(compare_paired(&base, &better, 5e-4), Comparison::Loss);
        assert_eq!(compare_paired(&base, &base, 5e-4), Comparison::Tie);
    }
}
