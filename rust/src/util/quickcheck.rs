//! Mini property-testing harness (the offline build has no proptest).
//!
//! `check(name, n_cases, |rng| { ... })` runs a property against `n_cases`
//! independently-seeded RNGs. On failure it panics with the failing case
//! seed so the case replays exactly:
//!
//! ```text
//! property 'storage_roundtrip' failed at case 17 (replay seed 0x1234...)
//! ```
//!
//! Properties draw their own inputs from the provided RNG, which keeps the
//! harness generator-free and the sampled space fully under test control.

use crate::util::rng::Pcg64;

/// Result of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` for `n_cases` seeded cases; panic on first failure with the
/// replay seed. Base seed is derived from the property name so adding new
/// properties doesn't shift existing ones.
pub fn check<F>(name: &str, n_cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    for case in 0..n_cases {
        let seed = base ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> CaseResult,
{
    let mut rng = Pcg64::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always_true", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always_false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn cases_get_distinct_seeds() {
        let mut values = Vec::new();
        check("distinct", 20, |rng| {
            values.push(rng.next_u64());
            Ok(())
        });
        let mut dedup = values.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), values.len());
    }

    #[test]
    fn replay_reproduces_case_values() {
        let mut first = None;
        check("replayable", 1, |rng| {
            first = Some(rng.next_u64());
            Ok(())
        });
        let base = fnv1a(b"replayable");
        let mut replayed = None;
        replay(base, |rng| {
            replayed = Some(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, replayed);
    }
}
