//! Minimal JSON: value type, recursive-descent parser, compact writer.
//!
//! Used by the journal storage (one JSON object per line), the artifact
//! manifest reader, study export, and the dashboard. The offline build has
//! no serde, so this stays small and dependency-free. Supports the full
//! JSON grammar except surrogate-pair escapes (sufficient for our data,
//! which is machine-generated ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (i64-exact integers round-trip
/// through the writer without a fractional part).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get("a")` convenience that tolerates non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_nan() || n.is_infinite() {
                    // JSON has no NaN/inf; journal entries encode them as strings
                    // at a higher level. Writing null here keeps output valid.
                    out.push_str("null");
                } else if *n == n.trunc() && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n:?}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut arr = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(arr));
            }
            loop {
                arr.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(arr));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}", pos = *pos));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => Err(format!("unexpected byte '{}' at {}", c as char, *pos)),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("invalid number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .ok_or_else(|| format!("bad \\u escape at byte {}", *pos))?;
                        out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            _ => {
                // copy a full UTF-8 scalar
                let s = &b[*pos..];
                let ch_len = utf8_len(s[0]);
                let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                    .map_err(|_| format!("invalid utf-8 at byte {}", *pos))?;
                out.push_str(chunk);
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":-2.5e3,"e":{}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-2500.0));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        let v = Json::Num(4e18); // too big for exact i64 — falls to debug float
        assert!(v.to_string().contains('e') || v.to_string().contains('.'));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é\t\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("é\t\\ A"));
        let s = Json::Str("tab\there \"q\" é".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("tab\there \"q\" é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nulL").is_err());
    }

    #[test]
    fn obj_helpers() {
        let v = Json::obj(vec![("k", Json::Num(1.0)), ("s", Json::Str("v".into()))]);
        assert_eq!(v.get("k").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("s").unwrap().as_str(), Some("v"));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn parses_manifest_like_doc() {
        let text = r#"{"programs":{"tpe_score":{"file":"tpe_score.hlo.txt",
            "inputs":[{"shape":[512],"dtype":"float32"}],
            "outputs":[{"shape":[512],"dtype":"float32"}]}}}"#;
        let v = Json::parse(text).unwrap();
        let ins = v
            .get("programs")
            .and_then(|p| p.get("tpe_score"))
            .and_then(|p| p.get("inputs"))
            .and_then(|i| i.as_arr())
            .unwrap();
        assert_eq!(ins[0].get("shape").unwrap().as_arr().unwrap()[0].as_i64(), Some(512));
    }
}
