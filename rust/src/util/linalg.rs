//! Small dense linear algebra for the relational samplers.
//!
//! CMA-ES needs a symmetric eigendecomposition (covariance adaptation);
//! the GP sampler needs Cholesky factorization and triangular solves.
//! Matrices are row-major `Vec<f64>`; sizes here are tiny (dimension of
//! the search space, ≤ a few dozen), so clarity beats blocking.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| (0..self.cols).map(|j| self[(i, j)] * v[j]).sum())
            .collect()
    }

    /// Frobenius-norm distance (test helper).
    pub fn dist(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization A = L·Lᵀ for symmetric positive-definite A.
/// Returns lower-triangular L, or None if A is not (numerically) SPD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, i)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L·y = b for lower-triangular L.
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve Lᵀ·x = y for lower-triangular L.
pub fn solve_lower_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Solve A·x = b via Cholesky (A SPD).
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Symmetric eigendecomposition via cyclic Jacobi rotations.
/// Returns (eigenvalues ascending, eigenvectors as columns of V) with
/// A = V·diag(λ)·Vᵀ.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // largest off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..n {
            for j in i + 1..n {
                off = off.max(m[(i, j)].abs());
            }
        }
        if off < 1e-13 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut idx: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| evals[a].partial_cmp(&evals[b]).unwrap());
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Mat::zeros(n, n);
    for (new_col, &old_col) in idx.iter().enumerate() {
        for r in 0..n {
            sorted_vecs[(r, new_col)] = v[(r, old_col)];
        }
    }
    (sorted_vals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(n: usize, rng: &mut Pcg64) -> Mat {
        let mut b = Mat::zeros(n, n);
        for i in 0..n * n {
            b.data[i] = rng.normal();
        }
        let mut a = b.matmul(&b.t());
        for i in 0..n {
            a[(i, i)] += n as f64; // ensure well-conditioned
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(1);
        let a = random_spd(4, &mut rng);
        let i = Mat::eye(4);
        assert!(a.matmul(&i).dist(&a) < 1e-12);
        assert!(i.matmul(&a).dist(&a) < 1e-12);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::new(2);
        for n in [1, 2, 5, 8] {
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).expect("SPD");
            assert!(l.matmul(&l.t()).dist(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigs 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_matches() {
        let mut rng = Pcg64::new(3);
        let a = random_spd(6, &mut rng);
        let x_true: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (xa, xb) in x.iter().zip(&x_true) {
            assert!((xa - xb).abs() < 1e-8);
        }
    }

    #[test]
    fn eigh_reconstructs_and_orthonormal() {
        let mut rng = Pcg64::new(4);
        for n in [2, 3, 6, 10] {
            let a = random_spd(n, &mut rng);
            let (vals, vecs) = eigh(&a);
            // ascending
            for w in vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
            // V diag(vals) V^T == A
            let mut d = Mat::zeros(n, n);
            for i in 0..n {
                d[(i, i)] = vals[i];
            }
            let rec = vecs.matmul(&d).matmul(&vecs.t());
            assert!(rec.dist(&a) < 1e-8, "n={n} dist={}", rec.dist(&a));
            // orthonormal
            assert!(vecs.t().matmul(&vecs).dist(&Mat::eye(n)) < 1e-9);
        }
    }

    #[test]
    fn eigh_known_2x2() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
    }
}
