//! Dependency-free utilities (the offline build carries its own RNG,
//! JSON, linear algebra, statistics, and property-test harness).

pub mod json;
pub mod linalg;
pub mod quickcheck;
pub mod rng;
pub mod stats;
