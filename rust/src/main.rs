//! `optuna` binary — see cli::run for the command set: the Fig 7
//! workflow (create-study/optimize/best/export/dashboard/studies) plus
//! the fault-tolerant distributed commands (`worker`, `distributed`).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(optuna_rs::cli::run(&argv));
}
