//! `optuna` binary — see cli::run for the command set (Fig 7 workflow).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(optuna_rs::cli::run(&argv));
}
