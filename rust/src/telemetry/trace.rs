//! Span-based tracing: a thread-local span stack for parent linkage and
//! a bounded ring buffer of finished-span events.
//!
//! A span is an RAII guard ([`SpanGuard`]): creation pushes onto the
//! current thread's stack, drop pops it and appends one [`SpanEvent`]
//! to the ring. The ring holds the most recent [`Tracer::capacity`]
//! events — memory is bounded no matter how long the process runs; a
//! `dropped` counter records how many events the window has evicted, so
//! offline analysis knows whether it is looking at a complete trace.
//!
//! Events export as JSONL ([`Tracer::export_jsonl`]): one self-contained
//! JSON object per line, the format every trace tool ingests without a
//! schema negotiation.

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// One finished span.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    /// Span name from the fixed taxonomy (docs/ARCHITECTURE.md
    /// §Telemetry): `study.ask`, `study.tell`, `sampler.suggest`, …
    pub name: &'static str,
    /// Process-unique span id.
    pub span_id: u64,
    /// Enclosing span on the same thread; 0 = root.
    pub parent_id: u64,
    /// Small process-local thread number (not the OS tid).
    pub thread: u64,
    /// Wall-clock start, microseconds since the Unix epoch.
    pub start_us: u64,
    /// Monotonic duration, microseconds.
    pub dur_us: u64,
}

impl SpanEvent {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("span", Json::Num(self.span_id as f64)),
            ("parent", Json::Num(self.parent_id as f64)),
            ("thread", Json::Num(self.thread as f64)),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
        ])
    }
}

/// Default ring capacity: 16k events ≈ a few MB worst case, hours of
/// trace at typical ask/tell rates.
pub const DEFAULT_CAPACITY: usize = 16 * 1024;

static NEXT_THREAD: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Stack of open span ids on this thread (parent linkage).
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// This thread's small id, assigned on first span.
    static THREAD_NO: u64 = NEXT_THREAD.fetch_add(1, Ordering::Relaxed);
}

/// Bounded event log + span-id allocator.
pub struct Tracer {
    events: Mutex<VecDeque<SpanEvent>>,
    capacity: usize,
    next_span: AtomicU64,
    dropped: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::with_capacity(DEFAULT_CAPACITY)
    }
}

impl Tracer {
    pub fn with_capacity(capacity: usize) -> Self {
        Tracer {
            events: Mutex::new(VecDeque::with_capacity(capacity.min(1024))),
            capacity: capacity.max(1),
            next_span: AtomicU64::new(1),
            dropped: AtomicU64::new(0),
        }
    }

    pub(crate) fn begin(&self) -> (u64, u64) {
        let span_id = self.next_span.fetch_add(1, Ordering::Relaxed);
        let parent = SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            let parent = s.last().copied().unwrap_or(0);
            s.push(span_id);
            parent
        });
        (span_id, parent)
    }

    pub(crate) fn end(&self, event: SpanEvent) {
        SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            // guards drop LIFO under normal control flow; be tolerant of
            // a leaked guard (mem::forget) and unwind out of order
            if s.last() == Some(&event.span_id) {
                s.pop();
            } else {
                s.retain(|&id| id != event.span_id);
            }
        });
        let mut q = self.events.lock().unwrap_or_else(|e| e.into_inner());
        if q.len() >= self.capacity {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(event);
    }

    /// Events evicted by the bounded window since process start.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy of the retained events, oldest first.
    pub fn events(&self) -> Vec<SpanEvent> {
        self.events
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .cloned()
            .collect()
    }

    /// One JSON object per line, oldest first — the offline-analysis
    /// export format.
    pub fn export_jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

pub(crate) fn thread_no() -> u64 {
    THREAD_NO.with(|t| *t)
}

pub(crate) fn wall_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros().min(u128::from(u64::MAX)) as u64)
        .unwrap_or(0)
}

/// RAII span handle from [`crate::telemetry::Telemetry::span`]. Inert
/// (all-`None`) when telemetry is disabled, so call sites pay one
/// branch and nothing else.
pub struct SpanGuard<'a> {
    pub(crate) inner: Option<ActiveSpan<'a>>,
}

pub(crate) struct ActiveSpan<'a> {
    pub(crate) tel: &'a crate::telemetry::Telemetry,
    pub(crate) name: &'static str,
    pub(crate) span_id: u64,
    pub(crate) parent_id: u64,
    pub(crate) start_wall_us: u64,
    pub(crate) start: Instant,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let Some(a) = self.inner.take() else { return };
        let dur = a.start.elapsed();
        a.tel.tracer().end(SpanEvent {
            name: a.name,
            span_id: a.span_id,
            parent_id: a.parent_id,
            thread: thread_no(),
            start_us: a.start_wall_us,
            dur_us: dur.as_micros().min(u128::from(u64::MAX)) as u64,
        });
        a.tel.span_histogram(a.name).record_duration(dur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::with_capacity(4);
        for i in 0..10 {
            let (id, parent) = t.begin();
            t.end(SpanEvent {
                name: "x",
                span_id: id,
                parent_id: parent,
                thread: 0,
                start_us: i,
                dur_us: 1,
            });
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        // oldest evicted: the survivors are the last four
        assert_eq!(t.events()[0].start_us, 6);
    }

    #[test]
    fn nesting_links_parents_on_one_thread() {
        let t = Tracer::default();
        let (outer, outer_parent) = t.begin();
        let (inner, inner_parent) = t.begin();
        assert_eq!(outer_parent, 0);
        assert_eq!(inner_parent, outer);
        t.end(SpanEvent {
            name: "inner",
            span_id: inner,
            parent_id: inner_parent,
            thread: 0,
            start_us: 0,
            dur_us: 1,
        });
        t.end(SpanEvent {
            name: "outer",
            span_id: outer,
            parent_id: outer_parent,
            thread: 0,
            start_us: 0,
            dur_us: 2,
        });
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn jsonl_lines_parse_individually() {
        let t = Tracer::default();
        let (id, parent) = t.begin();
        t.end(SpanEvent {
            name: "study.ask",
            span_id: id,
            parent_id: parent,
            thread: 3,
            start_us: 17,
            dur_us: 42,
        });
        let jsonl = t.export_jsonl();
        for line in jsonl.lines() {
            let v = Json::parse(line).expect("every line is a JSON object");
            assert_eq!(v.get("name").unwrap().as_str(), Some("study.ask"));
            assert_eq!(v.get("dur_us").unwrap().as_i64(), Some(42));
        }
    }
}
