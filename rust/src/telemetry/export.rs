//! Exporters: Prometheus text exposition format and a JSON snapshot.
//!
//! Histograms export as Prometheus *summaries* — `name{quantile="0.5"}`
//! plus `_sum`/`_count` — because the log-bucket histogram already
//! reduces to p50/p95/p99 server-side, and a summary is one line per
//! quantile instead of [`super::metrics::NUM_BUCKETS`] `_bucket` lines
//! per (op × metric) pair. Both exports render from one
//! [`RegistrySnapshot`], so the two views of a scrape always agree.

use super::metrics::RegistrySnapshot;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// `{k="v",…}` label block; empty string when there are no labels.
fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a snapshot in the Prometheus text exposition format (one
/// `# TYPE` header per metric family, deterministic order).
pub fn to_prometheus(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();
    let mut typed: BTreeMap<&str, &str> = BTreeMap::new();
    for ((name, _), _) in &snap.counters {
        typed.insert(name, "counter");
    }
    for ((name, _), _) in &snap.gauges {
        typed.insert(name, "gauge");
    }
    for ((name, _), _) in &snap.histograms {
        typed.insert(name, "summary");
    }
    for (name, kind) in &typed {
        let _ = writeln!(out, "# TYPE {name} {kind}");
        match *kind {
            "counter" => {
                for ((n, labels), v) in &snap.counters {
                    if n == name {
                        let _ = writeln!(out, "{n}{} {v}", label_block(labels, None));
                    }
                }
            }
            "gauge" => {
                for ((n, labels), v) in &snap.gauges {
                    if n == name {
                        let _ = writeln!(out, "{n}{} {v}", label_block(labels, None));
                    }
                }
            }
            _ => {
                for ((n, labels), h) in &snap.histograms {
                    if n == name {
                        for (q, v) in
                            [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)]
                        {
                            let _ = writeln!(
                                out,
                                "{n}{} {v:e}",
                                label_block(labels, Some(("quantile", q)))
                            );
                        }
                        let _ =
                            writeln!(out, "{n}_sum{} {:e}", label_block(labels, None), h.sum_secs);
                        let _ =
                            writeln!(out, "{n}_count{} {}", label_block(labels, None), h.count);
                    }
                }
            }
        }
    }
    out
}

/// One instrument's JSON identity: `{"name":…, "labels":{…}, …fields}`.
fn entry(name: &str, labels: &[(String, String)], fields: Vec<(&str, Json)>) -> Json {
    let label_obj = Json::Obj(
        labels
            .iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect(),
    );
    let mut pairs = vec![("name", Json::Str(name.to_string())), ("labels", label_obj)];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// Render a snapshot as one JSON document:
/// `{"counters":[…],"gauges":[…],"histograms":[…]}` — the machine-
/// readable twin of [`to_prometheus`], written by `--metrics-out` and
/// the `metrics` subcommand.
pub fn to_json(snap: &RegistrySnapshot) -> Json {
    let counters: Vec<Json> = snap
        .counters
        .iter()
        .map(|((name, labels), v)| {
            entry(name, labels, vec![("value", Json::Num(*v as f64))])
        })
        .collect();
    let gauges: Vec<Json> = snap
        .gauges
        .iter()
        .map(|((name, labels), v)| {
            entry(name, labels, vec![("value", Json::Num(*v as f64))])
        })
        .collect();
    let histograms: Vec<Json> = snap
        .histograms
        .iter()
        .map(|((name, labels), h)| {
            entry(
                name,
                labels,
                vec![
                    ("count", Json::Num(h.count as f64)),
                    ("sum_secs", Json::Num(h.sum_secs)),
                    ("p50", Json::Num(h.p50)),
                    ("p95", Json::Num(h.p95)),
                    ("p99", Json::Num(h.p99)),
                ],
            )
        })
        .collect();
    Json::obj(vec![
        ("counters", Json::Arr(counters)),
        ("gauges", Json::Arr(gauges)),
        ("histograms", Json::Arr(histograms)),
    ])
}

#[cfg(test)]
mod tests {
    use crate::telemetry::Telemetry;
    use crate::util::json::Json;

    #[test]
    fn prometheus_lines_are_well_formed() {
        let tel = Telemetry::new();
        tel.registry().counter("optuna_errors_total", &[("kind", "io")]).add(3);
        tel.registry().gauge("optuna_queue_depth", &[]).set(7);
        tel.registry()
            .histogram("optuna_op_seconds", &[("op", "ask")])
            .record_secs(0.001);
        let text = tel.to_prometheus();
        assert!(text.contains("# TYPE optuna_errors_total counter"));
        assert!(text.contains("optuna_errors_total{kind=\"io\"} 3"));
        assert!(text.contains("optuna_queue_depth 7"));
        assert!(text.contains("# TYPE optuna_op_seconds summary"));
        assert!(text.contains("optuna_op_seconds{op=\"ask\",quantile=\"0.5\"}"));
        assert!(text.contains("optuna_op_seconds_count{op=\"ask\"} 1"));
        // every non-comment line is `name{labels}? value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name_part, value) = line.rsplit_once(' ').expect("space-separated");
            assert!(!name_part.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparsable value in '{line}'");
        }
    }

    #[test]
    fn json_snapshot_parses_and_roundtrips_fields() {
        let tel = Telemetry::new();
        tel.registry()
            .histogram("optuna_op_seconds", &[("op", "tell")])
            .record_secs(0.25);
        let doc = Json::parse(&tel.to_json_string()).unwrap();
        let hists = doc.get("histograms").unwrap().as_arr().unwrap();
        assert_eq!(hists.len(), 1);
        assert_eq!(hists[0].get("name").unwrap().as_str(), Some("optuna_op_seconds"));
        assert_eq!(
            hists[0].get("labels").unwrap().get("op").unwrap().as_str(),
            Some("tell")
        );
        assert_eq!(hists[0].get("count").unwrap().as_i64(), Some(1));
    }
}
