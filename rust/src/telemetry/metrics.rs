//! Metric primitives: atomic counters, gauges, and fixed log-bucket
//! latency histograms with p50/p95/p99 readout — plus the registry
//! that names them.
//!
//! Everything here is designed for the storage/ask hot paths:
//!
//! * recording is lock-free (`Relaxed` atomics only) — a histogram
//!   observation is one `leading_zeros`, two `fetch_add`s, and nothing
//!   else;
//! * instruments are interned once and held as `Arc` handles by their
//!   call sites ([`crate::storage::TelemetryStorage`] pre-resolves one
//!   histogram per storage op at construction), so the registry's
//!   name→instrument map is off the hot path entirely;
//! * readout ([`MetricsRegistry::snapshot`]) is approximate by design:
//!   concurrent writers may land between bucket reads. That is the
//!   standard monitoring trade — metrics are for operators, not for
//!   invariants.
//!
//! Memory is statically bounded: a histogram is [`NUM_BUCKETS`] `u64`s,
//! and the registry only grows with distinct (name, labels) pairs,
//! which instrumentation sites draw from fixed vocabularies (op names,
//! span names, [`crate::core::ErrorKind`] strings).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic event counter.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (set from folded stats like
/// [`crate::storage::ResilienceStats`], journal sizes, queue depths).
#[derive(Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, delta: i64) {
        self.value.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Bucket count of the log-scale histogram: bucket `i` holds samples
/// whose value in nanoseconds needs `i` significant bits, i.e. bucket
/// upper bounds run 1ns, 1ns, 3ns, 7ns, … `2^(i)-1`ns — ~48 buckets
/// cover 0ns to ~3.2 days, which is every latency this system can
/// produce, with ≤2x relative error. The last bucket is the overflow
/// bucket: anything past ~1.6 days saturates into it.
pub const NUM_BUCKETS: usize = 48;

/// Fixed log-bucket latency histogram. Values are recorded in
/// nanoseconds ([`Histogram::record_ns`] / [`Histogram::record_secs`]);
/// quantile readout returns seconds.
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    /// Sum in nanoseconds (u64 wraps after ~584 years of accumulated
    /// latency; acceptable for a process-lifetime metric).
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: [(); NUM_BUCKETS].map(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

/// Bucket index of a nanosecond value: its bit length, clamped into the
/// overflow bucket.
fn bucket_of(ns: u64) -> usize {
    (64 - ns.leading_zeros() as usize).min(NUM_BUCKETS - 1)
}

/// Upper bound (inclusive, in ns) of bucket `i` — the value reported
/// for quantiles that land in it. The overflow bucket reports its
/// *lower* bound: "at least this much" is the only honest claim there.
fn bucket_bound_ns(i: usize) -> u64 {
    if i >= NUM_BUCKETS - 1 {
        return 1u64 << (NUM_BUCKETS - 2); // overflow: lower bound
    }
    (1u64 << i) - 1 + u64::from(i == 0)
}

impl Histogram {
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Record a latency given in seconds. Non-finite or negative values
    /// (a NaN from a degenerate rate computation, for instance) are
    /// dropped rather than poisoning the distribution.
    pub fn record_secs(&self, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        self.record_ns((secs * 1e9).min(u64::MAX as f64) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total observed time in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Quantile readout in seconds (`q` in [0, 1]); `None` on an empty
    /// histogram. The answer is the bucket bound containing the target
    /// rank, so it is exact to within one bucket (≤2x).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        // rank of the target sample, 1-based; q=0 reads the first sample
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(bucket_bound_ns(i) as f64 / 1e9);
            }
        }
        unreachable!("rank <= total")
    }

    /// The (p50, p95, p99) triple, `None` when empty.
    pub fn percentiles(&self) -> Option<(f64, f64, f64)> {
        Some((self.quantile(0.50)?, self.quantile(0.95)?, self.quantile(0.99)?))
    }
}

/// A metric's identity: name plus sorted label pairs.
type MetricId = (String, Vec<(String, String)>);

fn id_of(name: &str, labels: &[(&str, &str)]) -> MetricId {
    let mut l: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    l.sort();
    (name.to_string(), l)
}

/// Named instrument store. `counter`/`gauge`/`histogram` intern on
/// first use and return shared handles; hold the handle on hot paths
/// instead of re-resolving.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<MetricId, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<MetricId, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<MetricId, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        map.entry(id_of(name, labels)).or_default().clone()
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        map.entry(id_of(name, labels)).or_default().clone()
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap_or_else(|e| e.into_inner());
        map.entry(id_of(name, labels)).or_default().clone()
    }

    /// Point-in-time copy of every instrument, for export. Counters and
    /// gauges are plain values; histograms carry (count, sum, p50/95/99).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(id, c)| (id.clone(), c.get()))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(id, g)| (id.clone(), g.get()))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(id, h)| {
                let (p50, p95, p99) = h.percentiles().unwrap_or((0.0, 0.0, 0.0));
                (
                    id.clone(),
                    HistogramSnapshot { count: h.count(), sum_secs: h.sum_secs(), p50, p95, p99 },
                )
            })
            .collect();
        RegistrySnapshot { counters, gauges, histograms }
    }
}

/// Frozen view of one histogram (quantiles in seconds; all-zero when
/// the histogram never recorded).
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_secs: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Frozen view of a whole registry — what the Prometheus/JSON exporters
/// and the dashboard render. Maps are sorted by (name, labels), so
/// export output is deterministic.
pub struct RegistrySnapshot {
    pub counters: BTreeMap<MetricId, u64>,
    pub gauges: BTreeMap<MetricId, i64>,
    pub histograms: BTreeMap<MetricId, HistogramSnapshot>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = MetricsRegistry::default();
        let c = r.counter("ops", &[("op", "ask")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same (name, labels) in any order interns to the same instrument
        assert_eq!(r.counter("ops", &[("op", "ask")]).get(), 5);
        let g = r.gauge("depth", &[]);
        g.set(-3);
        g.add(5);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn histogram_buckets_are_log_scale() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn quantiles_land_in_the_right_buckets() {
        let h = Histogram::default();
        for _ in 0..99 {
            h.record_ns(1_000); // ~1us
        }
        h.record_ns(1_000_000_000); // one 1s outlier
        let (p50, _, p99) = h.percentiles().unwrap();
        assert!(p50 < 3e-6, "p50 {p50} should be ~1us");
        assert!(p99 < 3e-6, "p99 {p99} covers rank 99 of 100, still ~1us");
        assert!(h.quantile(1.0).unwrap() >= 0.5, "max sees the outlier");
    }

    #[test]
    fn snapshot_is_deterministic() {
        let r = MetricsRegistry::default();
        r.histogram("h", &[("op", "b")]).record_ns(10);
        r.histogram("h", &[("op", "a")]).record_ns(10);
        let snap = r.snapshot();
        let names: Vec<_> = snap.histograms.keys().cloned().collect();
        assert_eq!(names[0].1[0].1, "a");
        assert_eq!(names[1].1[0].1, "b");
    }
}
