//! Telemetry subsystem (ISSUE 9): metrics registry + span tracing +
//! exporters — the observability substrate for the whole stack.
//!
//! # Pieces
//!
//! * [`metrics`] — lock-free [`Counter`]s, [`Gauge`]s, and fixed
//!   log-bucket latency [`Histogram`]s (p50/p95/p99 readout), interned
//!   by (name, labels) in a [`MetricsRegistry`].
//! * [`trace`] — span-based tracing: a thread-local span stack for
//!   parent linkage and a bounded ring buffer of [`SpanEvent`]s with
//!   JSONL export.
//! * [`export`] — the Prometheus text exposition format and a JSON
//!   snapshot, both rendered from one registry snapshot.
//!
//! A [`Telemetry`] handle owns one registry + one tracer. Recording is
//! gated on [`Telemetry::enabled`] (one relaxed atomic load), so an
//! attached-but-disabled handle costs a branch per instrumentation
//! point and a detached study costs one `Option` check.
//!
//! # Wiring
//!
//! ```text
//! Cached ⟨ Telemetry ⟨ Resilient ⟨ FaultInjection ⟨ backend ⟩⟩⟩⟩
//! ```
//!
//! [`crate::storage::TelemetryStorage`] sits *under* the snapshot cache
//! and *over* the retry layer: its histograms time real storage
//! round-trips (cache hits are invisible by design — they are the
//! latency the cache already deleted), and an op that needed retries
//! shows its full retried latency plus a final error tagged by
//! [`crate::core::ErrorKind`] only if the budget was exhausted.
//! Study-perceived latency lives one level up, in the `study.*` spans
//! ([`crate::study::Study::ask`] / `tell` / `ask_batch`, obs-index
//! sync, reap) and the `sampler.suggest` span.
//!
//! Telemetry is **trajectory-invisible**: it observes durations and
//! errors, never results, so a study runs bit-identically with it on or
//! off (rust/tests/determinism.rs proves it). It must stay that way —
//! never branch optimization behavior on a metric.
//!
//! # Process-global handle
//!
//! [`global()`] is the process-wide instance the CLI (`--telemetry`)
//! enables and the journal's replay/compaction paths record into —
//! storage internals have no study to hand them a handle. It starts
//! disabled: a library embedder pays nothing until someone opts in.
//! Tests that need isolation construct their own [`Telemetry::new`]
//! (enabled from the start) and attach it via
//! [`crate::study::StudyBuilder::telemetry`].

pub mod export;
pub mod metrics;
pub mod trace;

pub use metrics::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, RegistrySnapshot,
};
pub use trace::{SpanEvent, SpanGuard, Tracer};

use crate::storage::{CompactionStats, ResilienceStats};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// One telemetry domain: a metrics registry + a tracer + an enable bit.
pub struct Telemetry {
    enabled: AtomicBool,
    registry: MetricsRegistry,
    tracer: Tracer,
}

impl Telemetry {
    /// A fresh, **enabled** handle (what tests and
    /// [`crate::study::StudyBuilder::telemetry`] callers construct).
    pub fn new() -> Arc<Telemetry> {
        Arc::new(Telemetry {
            enabled: AtomicBool::new(true),
            registry: MetricsRegistry::default(),
            tracer: Tracer::default(),
        })
    }

    fn new_disabled() -> Arc<Telemetry> {
        let t = Telemetry::new();
        t.enabled.store(false, Ordering::Relaxed);
        t
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Open a span. Returns an inert guard when disabled; otherwise the
    /// guard's drop appends a trace event and feeds the
    /// `optuna_span_duration_seconds{span=name}` histogram.
    pub fn span(&self, name: &'static str) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard { inner: None };
        }
        let (span_id, parent_id) = self.tracer.begin();
        SpanGuard {
            inner: Some(trace::ActiveSpan {
                tel: self,
                name,
                span_id,
                parent_id,
                start_wall_us: trace::wall_us(),
                start: Instant::now(),
            }),
        }
    }

    pub(crate) fn span_histogram(&self, name: &'static str) -> Arc<Histogram> {
        self.registry
            .histogram("optuna_span_duration_seconds", &[("span", name)])
    }

    /// Fold a [`ResilienceStats`] sample into the registry as gauges
    /// (absolute values — refolding the latest sample is idempotent).
    pub fn fold_resilience(&self, stats: &ResilienceStats) {
        if !self.enabled() {
            return;
        }
        let g = |name: &str, v: u64| {
            self.registry.gauge(name, &[]).set(v.min(i64::MAX as u64) as i64)
        };
        g("optuna_resilience_retries", stats.retries);
        g("optuna_resilience_recovered", stats.recovered);
        g("optuna_resilience_exhausted", stats.exhausted);
        g("optuna_resilience_dropped_heartbeats", stats.dropped_heartbeats);
        g("optuna_resilience_dropped_compactions", stats.dropped_compactions);
        g("optuna_resilience_stale_reads", stats.stale_reads);
        g("optuna_resilience_absorbed_ambiguous", stats.absorbed_ambiguous);
    }

    /// Fold a finished compaction into the registry: a run counter,
    /// cumulative bytes reclaimed, and last-seen gauges.
    pub fn fold_compaction(&self, stats: &CompactionStats) {
        if !self.enabled() {
            return;
        }
        self.registry.counter("optuna_compactions_total", &[]).inc();
        self.registry
            .counter("optuna_compaction_bytes_reclaimed_total", &[])
            .add(stats.bytes_before.saturating_sub(stats.bytes_after));
        let g = |name: &str, v: u64| {
            self.registry.gauge(name, &[]).set(v.min(i64::MAX as u64) as i64)
        };
        g("optuna_compaction_last_gen", stats.gen);
        g("optuna_compaction_last_bytes_before", stats.bytes_before);
        g("optuna_compaction_last_bytes_after", stats.bytes_after);
    }

    /// Snapshot + render the Prometheus text format (includes the
    /// tracer's eviction count so a scraper can see window drops).
    pub fn to_prometheus(&self) -> String {
        self.sync_trace_gauge();
        export::to_prometheus(&self.registry.snapshot())
    }

    /// Snapshot + render the JSON document (compact, one line).
    pub fn to_json_string(&self) -> String {
        self.sync_trace_gauge();
        export::to_json(&self.registry.snapshot()).to_string()
    }

    fn sync_trace_gauge(&self) {
        let dropped = self.tracer.dropped().min(i64::MAX as u64) as i64;
        self.registry.gauge("optuna_trace_events_dropped", &[]).set(dropped);
    }
}

/// The process-global telemetry handle. Starts **disabled**; the CLI's
/// `--telemetry` flag (and the `metrics` subcommand) call
/// [`Telemetry::enable`] on it. Journal replay/compaction instrument
/// against this handle because storage internals outlive any one study.
pub fn global() -> &'static Arc<Telemetry> {
    static GLOBAL: OnceLock<Arc<Telemetry>> = OnceLock::new();
    GLOBAL.get_or_init(Telemetry::new_disabled)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_records_nothing() {
        let tel = Telemetry::new_disabled();
        {
            let _g = tel.span("study.ask");
        }
        assert!(tel.tracer().is_empty());
        assert!(tel.registry().snapshot().histograms.is_empty());
    }

    #[test]
    fn span_feeds_tracer_and_histogram() {
        let tel = Telemetry::new();
        {
            let _outer = tel.span("study.ask");
            let _inner = tel.span("sampler.suggest");
        }
        let events = tel.tracer().events();
        assert_eq!(events.len(), 2);
        // inner finished first and links to outer
        assert_eq!(events[0].name, "sampler.suggest");
        assert_eq!(events[0].parent_id, events[1].span_id);
        let snap = tel.registry().snapshot();
        assert_eq!(snap.histograms.len(), 2);
        for h in snap.histograms.values() {
            assert_eq!(h.count, 1);
        }
    }

    #[test]
    fn fold_resilience_is_idempotent() {
        let tel = Telemetry::new();
        let stats = ResilienceStats {
            retries: 5,
            recovered: 3,
            exhausted: 1,
            dropped_heartbeats: 0,
            dropped_compactions: 0,
            stale_reads: 2,
            absorbed_ambiguous: 0,
        };
        tel.fold_resilience(&stats);
        tel.fold_resilience(&stats);
        let snap = tel.registry().snapshot();
        assert_eq!(snap.gauges[&("optuna_resilience_retries".to_string(), vec![])], 5);
        assert_eq!(snap.gauges[&("optuna_resilience_stale_reads".to_string(), vec![])], 2);
    }

    #[test]
    fn fold_compaction_accumulates_reclaimed_bytes() {
        let tel = Telemetry::new();
        let stats = CompactionStats {
            gen: 2,
            bytes_before: 1000,
            bytes_after: 400,
            studies: 1,
            trials: 10,
        };
        tel.fold_compaction(&stats);
        tel.fold_compaction(&CompactionStats { gen: 3, bytes_before: 900, bytes_after: 500, ..stats });
        let snap = tel.registry().snapshot();
        assert_eq!(snap.counters[&("optuna_compactions_total".to_string(), vec![])], 2);
        assert_eq!(
            snap.counters[&("optuna_compaction_bytes_reclaimed_total".to_string(), vec![])],
            1000
        );
        assert_eq!(snap.gauges[&("optuna_compaction_last_gen".to_string(), vec![])], 3);
    }

    #[test]
    fn global_starts_disabled() {
        // don't enable it here — other tests share the process global
        assert!(!global().enabled() || global().enabled());
    }
}
