//! Intersection search-space inference (§3.1).
//!
//! On a define-by-run (dynamically constructed) space, the concurrence
//! relations between parameters are not declared up front. Following the
//! paper, the framework identifies them *from data*: the intersection of
//! the parameter sets of all completed trials is the subspace in which
//! every past trial is informative, and is therefore safe for relational
//! samplers (CMA-ES, GP) to model jointly.

use crate::core::{FrozenTrial, TrialState};
use crate::sampler::{SearchSpace, StudyContext};

/// Intersection search space for a sampler context: served from the
/// incrementally-maintained observation index in O(p) when present
/// (see [`crate::core::IndexSnapshot::intersection_space`]), otherwise
/// recomputed by scanning every completed trial. Relational samplers
/// (CMA-ES, GP, RF, group-TPE) call this once per ask, so on large
/// studies the index turns their space inference from O(n·p) into O(p).
pub fn intersection_search_space_ctx(ctx: &StudyContext<'_>) -> SearchSpace {
    match ctx.index {
        Some(ix) => ix.intersection_space(),
        None => intersection_search_space(ctx.trials),
    }
}

/// Compute the intersection search space over completed trials: parameters
/// present — with identical distributions — in every completed trial.
/// Single-valued distributions are excluded (nothing to optimize).
pub fn intersection_search_space(trials: &[FrozenTrial]) -> SearchSpace {
    let mut completed = trials
        .iter()
        .filter(|t| t.state == TrialState::Complete);
    let mut space: SearchSpace = match completed.next() {
        None => return SearchSpace::new(),
        Some(first) => first
            .params
            .iter()
            .map(|(name, (dist, _))| (name.clone(), dist.clone()))
            .collect(),
    };
    for t in completed {
        space.retain(|name, dist| {
            t.params
                .get(name)
                .map(|(d, _)| d == dist)
                .unwrap_or(false)
        });
        if space.is_empty() {
            break;
        }
    }
    space.retain(|_, dist| !dist.is_single());
    space
}

/// The subset of `space` a trial has values for, as an ordered vector —
/// the fixed coordinate layout relational samplers use.
pub fn trial_coords(trial: &FrozenTrial, space: &SearchSpace) -> Option<Vec<f64>> {
    let mut out = Vec::with_capacity(space.len());
    for (name, dist) in space {
        match trial.params.get(name) {
            Some((d, v)) if d == dist => out.push(*v),
            _ => return None,
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Distribution, ParamValue};
    use crate::sampler::testutil::completed_trial;

    #[test]
    fn empty_for_no_trials() {
        assert!(intersection_search_space(&[]).is_empty());
    }

    #[test]
    fn intersection_drops_branch_only_params() {
        let d_lr = Distribution::log_float(1e-5, 1e-1);
        let d_units = Distribution::int(1, 128);
        let t1 = completed_trial(
            0,
            &[
                ("lr", d_lr.clone(), ParamValue::Float(1e-3)),
                ("units", d_units.clone(), ParamValue::Int(64)),
            ],
            0.5,
        );
        let t2 = completed_trial(
            1,
            &[("lr", d_lr.clone(), ParamValue::Float(1e-2))],
            0.4,
        );
        let space = intersection_search_space(&[t1, t2]);
        assert_eq!(space.len(), 1);
        assert!(space.contains_key("lr"));
    }

    #[test]
    fn distribution_mismatch_excludes() {
        let t1 = completed_trial(
            0,
            &[("x", Distribution::float(0.0, 1.0), ParamValue::Float(0.5))],
            0.1,
        );
        let t2 = completed_trial(
            1,
            &[("x", Distribution::float(0.0, 2.0), ParamValue::Float(0.5))],
            0.2,
        );
        assert!(intersection_search_space(&[t1, t2]).is_empty());
    }

    #[test]
    fn running_trials_ignored() {
        let t1 = completed_trial(
            0,
            &[("x", Distribution::float(0.0, 1.0), ParamValue::Float(0.5))],
            0.1,
        );
        let mut t2 = crate::core::FrozenTrial::new(1, 1);
        t2.params
            .insert("y".into(), (Distribution::float(0.0, 1.0), 0.1));
        // t2 still Running: must not restrict the intersection
        let space = intersection_search_space(&[t1, t2]);
        assert_eq!(space.len(), 1);
        assert!(space.contains_key("x"));
    }

    #[test]
    fn single_valued_excluded() {
        let t = completed_trial(
            0,
            &[
                ("fixed", Distribution::float(2.0, 2.0), ParamValue::Float(2.0)),
                ("free", Distribution::float(0.0, 1.0), ParamValue::Float(0.3)),
            ],
            0.0,
        );
        let space = intersection_search_space(&[t]);
        assert!(!space.contains_key("fixed"));
        assert!(space.contains_key("free"));
    }

    #[test]
    fn trial_coords_ordering_and_missing() {
        let d = Distribution::float(0.0, 1.0);
        let t = completed_trial(
            0,
            &[
                ("b", d.clone(), ParamValue::Float(0.2)),
                ("a", d.clone(), ParamValue::Float(0.1)),
            ],
            0.0,
        );
        let mut space = SearchSpace::new();
        space.insert("a".into(), d.clone());
        space.insert("b".into(), d.clone());
        assert_eq!(trial_coords(&t, &space), Some(vec![0.1, 0.2]));
        space.insert("c".into(), d.clone());
        assert_eq!(trial_coords(&t, &space), None);
    }
}
