//! Random-forest EI sampler — the SMAC3 adversary of Fig 9/10.
//!
//! SMAC (Hutter et al. 2011) replaces the GP surrogate with a random
//! forest whose across-tree variance provides the uncertainty estimate
//! for expected improvement. This implementation: bootstrap-bagged
//! regression trees with random split dimensions over the normalized
//! intersection space, EI maximized over random + incumbent-jitter
//! candidates.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::core::{Distribution, TrialState};
use crate::sampler::random::RandomSampler;
use crate::sampler::search_space::{intersection_search_space_ctx, trial_coords};
use crate::sampler::{Sampler, SearchSpace, StudyContext};
use crate::util::rng::Pcg64;
use crate::util::stats::{erf, mean};

/// One regression-tree node (index-based arena).
enum Node {
    Leaf { value: f64 },
    Split { dim: usize, threshold: f64, left: usize, right: usize },
}

struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &mut [usize],
        max_depth: usize,
        min_leaf: usize,
        rng: &mut Pcg64,
    ) -> Tree {
        let mut tree = Tree { nodes: Vec::new() };
        tree.build(xs, ys, idx, max_depth, min_leaf, rng);
        tree
    }

    fn build(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        idx: &mut [usize],
        depth: usize,
        min_leaf: usize,
        rng: &mut Pcg64,
    ) -> usize {
        let node_mean = mean(&idx.iter().map(|&i| ys[i]).collect::<Vec<_>>());
        if depth == 0 || idx.len() < 2 * min_leaf {
            self.nodes.push(Node::Leaf { value: node_mean });
            return self.nodes.len() - 1;
        }
        let dim_count = xs[0].len();
        // try a few random (dim, threshold) splits; keep the best SSE drop
        let mut best: Option<(f64, usize, f64)> = None;
        for _ in 0..(dim_count.max(4)) {
            let d = rng.index(dim_count);
            let pivot = xs[idx[rng.index(idx.len())]][d];
            let (mut ln, mut ls, mut rn, mut rs) = (0usize, 0.0f64, 0usize, 0.0f64);
            for &i in idx.iter() {
                if xs[i][d] < pivot {
                    ln += 1;
                    ls += ys[i];
                } else {
                    rn += 1;
                    rs += ys[i];
                }
            }
            if ln < min_leaf || rn < min_leaf {
                continue;
            }
            // negative within-split SSE proxy: maximize separation
            let lm = ls / ln as f64;
            let rm = rs / rn as f64;
            let gain = (ln as f64) * lm * lm + (rn as f64) * rm * rm;
            if best.map(|(g, _, _)| gain > g).unwrap_or(true) {
                best = Some((gain, d, pivot));
            }
        }
        let Some((_, d, pivot)) = best else {
            self.nodes.push(Node::Leaf { value: node_mean });
            return self.nodes.len() - 1;
        };
        // partition in place
        let mut left: Vec<usize> = Vec::new();
        let mut right: Vec<usize> = Vec::new();
        for &i in idx.iter() {
            if xs[i][d] < pivot {
                left.push(i);
            } else {
                right.push(i);
            }
        }
        let placeholder = self.nodes.len();
        self.nodes.push(Node::Leaf { value: node_mean }); // replaced below
        let l = self.build(xs, ys, &mut left, depth - 1, min_leaf, rng);
        let r = self.build(xs, ys, &mut right, depth - 1, min_leaf, rng);
        self.nodes[placeholder] = Node::Split { dim: d, threshold: pivot, left: l, right: r };
        placeholder
    }

    fn predict(&self, x: &[f64]) -> f64 {
        // root is node 0 (build pushes it first)
        let mut cur = 0usize;
        loop {
            match &self.nodes[cur] {
                Node::Leaf { value } => return *value,
                Node::Split { dim, threshold, left, right } => {
                    cur = if x[*dim] < *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// SMAC-style random-forest sampler.
pub struct RfSampler {
    rng: Mutex<Pcg64>,
    fallback: RandomSampler,
    pub n_startup_trials: usize,
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_leaf: usize,
    pub n_candidates: usize,
    pub max_observations: usize,
}

impl RfSampler {
    pub fn new(seed: u64) -> Self {
        RfSampler {
            rng: Mutex::new(Pcg64::new(seed)),
            fallback: RandomSampler::new(seed ^ 0x5fac),
            n_startup_trials: 5,
            n_trees: 16,
            max_depth: 8,
            min_leaf: 2,
            n_candidates: 256,
            max_observations: 300,
        }
    }

    /// Registry constructor (spec `rf:trees=32,depth=10,...`).
    pub fn from_config(
        cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        let mut s = RfSampler::new(seed);
        if let Some(v) = cfg.get_usize("n_startup")? {
            s.n_startup_trials = v;
        }
        if let Some(v) = cfg.get_usize("trees")? {
            if v == 0 {
                return Err("trees must be >= 1".into());
            }
            s.n_trees = v;
        }
        if let Some(v) = cfg.get_usize("depth")? {
            if v == 0 {
                return Err("depth must be >= 1".into());
            }
            s.max_depth = v;
        }
        if let Some(v) = cfg.get_usize("min_leaf")? {
            if v == 0 {
                return Err("min_leaf must be >= 1".into());
            }
            s.min_leaf = v;
        }
        if let Some(v) = cfg.get_usize("candidates")? {
            if v == 0 {
                return Err("candidates must be >= 1".into());
            }
            s.n_candidates = v;
        }
        if let Some(v) = cfg.get_usize("max_obs")? {
            if v == 0 {
                return Err("max_obs must be >= 1".into());
            }
            s.max_observations = v;
        }
        Ok(s)
    }

    fn normal_cdf(z: f64) -> f64 {
        0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
    }

    fn normal_pdf(z: f64) -> f64 {
        (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
    }

    fn ei(mu: f64, sigma: f64, best: f64) -> f64 {
        if sigma <= 1e-12 {
            return (best - mu).max(0.0);
        }
        let z = (best - mu) / sigma;
        (best - mu) * Self::normal_cdf(z) + sigma * Self::normal_pdf(z)
    }
}

impl Sampler for RfSampler {
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace {
        let mut space = intersection_search_space_ctx(ctx);
        space.retain(|_, d| !matches!(d, Distribution::Categorical { .. }));
        if space.is_empty() || ctx.complete().count() < self.n_startup_trials {
            return SearchSpace::new();
        }
        space
    }

    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        _trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        if space.is_empty() {
            return BTreeMap::new();
        }
        let sign = ctx.direction.min_sign();
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for t in ctx
            .trials
            .iter()
            .filter(|t| t.state == TrialState::Complete)
            .rev()
            .take(self.max_observations)
        {
            if let (Some(v), Some(coords)) = (t.value, trial_coords(t, space)) {
                let norm: Vec<f64> = coords
                    .iter()
                    .zip(space.values())
                    .map(|(c, d)| {
                        let (lo, hi) = d.internal_range();
                        if hi <= lo { 0.5 } else { ((c - lo) / (hi - lo)).clamp(0.0, 1.0) }
                    })
                    .collect();
                xs.push(norm);
                ys.push(sign * v);
            }
        }
        if xs.len() < 2 {
            return BTreeMap::new();
        }
        let mut rng = self.rng.lock().unwrap();
        // bootstrap-bagged forest
        let n = xs.len();
        let trees: Vec<Tree> = (0..self.n_trees)
            .map(|_| {
                let mut idx: Vec<usize> = (0..n).map(|_| rng.index(n)).collect();
                Tree::fit(&xs, &ys, &mut idx, self.max_depth, self.min_leaf, &mut rng)
            })
            .collect();
        let best_y = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let incumbent = xs[ys
            .iter()
            .enumerate()
            .min_by(|a, b| crate::util::stats::nan_max_cmp(a.1, b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)]
        .clone();
        let dim = space.len();
        let mut best_cand: Option<(f64, Vec<f64>)> = None;
        for c in 0..self.n_candidates {
            let cand: Vec<f64> = if c % 4 == 0 {
                incumbent
                    .iter()
                    .map(|v| (v + 0.05 * rng.normal()).clamp(0.0, 1.0))
                    .collect()
            } else {
                (0..dim).map(|_| rng.uniform()).collect()
            };
            let preds: Vec<f64> = trees.iter().map(|t| t.predict(&cand)).collect();
            let mu = mean(&preds);
            let var = preds.iter().map(|p| (p - mu) * (p - mu)).sum::<f64>()
                / preds.len() as f64;
            let ei = Self::ei(mu, var.sqrt().max(1e-9), best_y);
            if best_cand.as_ref().map(|(b, _)| ei > *b).unwrap_or(true) {
                best_cand = Some((ei, cand));
            }
        }
        drop(rng);
        let chosen = best_cand.map(|(_, c)| c).unwrap_or(incumbent);
        space
            .iter()
            .zip(chosen)
            .map(|((name, dist), u)| {
                let (lo, hi) = dist.internal_range();
                (name.clone(), lo + u * (hi - lo))
            })
            .collect()
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        self.fallback.sample_independent(ctx, trial_number, name, dist)
    }

    fn name(&self) -> &'static str {
        "rf"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FrozenTrial, ParamValue, StudyDirection};
    use crate::sampler::testutil::completed_trial;

    #[test]
    fn tree_fits_step_function() {
        let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64 / 49.0]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| if x[0] < 0.5 { 0.0 } else { 1.0 }).collect();
        let mut rng = Pcg64::new(0);
        let mut idx: Vec<usize> = (0..50).collect();
        let tree = Tree::fit(&xs, &ys, &mut idx, 6, 2, &mut rng);
        assert!(tree.predict(&[0.1]) < 0.3);
        assert!(tree.predict(&[0.9]) > 0.7);
    }

    #[test]
    fn forest_concentrates_near_minimum() {
        let d = Distribution::float(0.0, 1.0);
        let trials: Vec<FrozenTrial> = (0..30)
            .map(|i| {
                let x = i as f64 / 29.0;
                completed_trial(
                    i,
                    &[("x", d.clone(), ParamValue::Float(x))],
                    (x - 0.7) * (x - 0.7),
                )
            })
            .collect();
        let s = RfSampler::new(1);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        let space = s.infer_relative_search_space(&ctx);
        assert_eq!(space.len(), 1);
        let mut hits = 0;
        for i in 0..20 {
            let rel = s.sample_relative(&ctx, 30 + i, &space);
            if (rel["x"] - 0.7).abs() < 0.2 {
                hits += 1;
            }
        }
        assert!(hits >= 10, "hits={hits}");
    }

    #[test]
    fn startup_empty_space() {
        let s = RfSampler::new(2);
        let d = Distribution::float(0.0, 1.0);
        let trials: Vec<FrozenTrial> = (0..2)
            .map(|i| completed_trial(i, &[("x", d.clone(), ParamValue::Float(0.1))], 1.0))
            .collect();
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        assert!(s.infer_relative_search_space(&ctx).is_empty());
    }

    use crate::util::rng::Pcg64;
}
