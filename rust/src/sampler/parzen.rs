//! Parzen estimator — the density model inside TPE.
//!
//! Fits a weighted mixture of Gaussians truncated to the search interval
//! to a set of 1-D observations (Bergstra et al. 2011, with Optuna's
//! bandwidth heuristics: neighbor-distance bandwidths, the "magic clip"
//! floor, and a wide prior component over the whole interval).
//!
//! The *same formulas* back three implementations that must agree:
//!  * this native scorer (`logpdf`),
//!  * the L1 Pallas kernel (python/compile/kernels/tpe_score.py), and
//!  * the pure-jnp oracle (ref.py) both are tested against.
//! Cross-language parity is asserted by rust/tests/tpe_parity.rs on the
//! fixture vectors `make artifacts` writes.

use crate::util::stats::erf;

/// Shared numerical floor (== ref.py EPS).
pub const EPS: f64 = 1e-12;

/// A truncated-Gaussian mixture on [low, high].
#[derive(Debug, Clone)]
pub struct ParzenEstimator {
    pub mus: Vec<f64>,
    pub sigmas: Vec<f64>,
    /// Unnormalized weights (normalized inside logpdf).
    pub weights: Vec<f64>,
    pub low: f64,
    pub high: f64,
}

/// Standard normal CDF — shared with the batched kernels
/// (`sampler/kernels/tpe_score.rs`), which must evaluate the truncation
/// mass with the identical expression to stay bit-equal to [`ParzenEstimator::logpdf`].
pub(crate) fn ndtr(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

impl Default for ParzenEstimator {
    /// An unfitted placeholder (prior over [0, 1]); call
    /// [`ParzenEstimator::fit_into`] before use.
    fn default() -> Self {
        ParzenEstimator::fit(&[], 0.0, 1.0)
    }
}

impl ParzenEstimator {
    /// Fit to observations (internal-representation values in [low, high]).
    ///
    /// * bandwidth of observation i = max(distance to left/right neighbor)
    ///   over the sorted observations extended by the interval bounds;
    /// * "magic clip": bandwidths floored at (high−low)/min(100, 1+n);
    /// * a prior component N(midpoint, high−low) with equal weight, which
    ///   keeps exploration alive for small n.
    pub fn fit(observations: &[f64], low: f64, high: f64) -> ParzenEstimator {
        let mut pe = ParzenEstimator {
            mus: Vec::with_capacity(observations.len() + 1),
            sigmas: Vec::with_capacity(observations.len() + 1),
            weights: Vec::with_capacity(observations.len() + 1),
            low,
            high,
        };
        pe.fit_into(observations, low, high);
        pe
    }

    /// [`Self::fit`] into an existing estimator, reusing its buffer
    /// allocations — the TPE hot path refits two estimators per suggest
    /// and would otherwise churn three Vecs each.
    pub fn fit_into(&mut self, observations: &[f64], low: f64, high: f64) {
        assert!(low < high, "degenerate interval [{low}, {high}]");
        self.low = low;
        self.high = high;
        self.mus.clear();
        self.sigmas.clear();
        self.weights.clear();
        let n = observations.len();
        let interval = high - low;
        if n == 0 {
            // prior only
            self.mus.push(0.5 * (low + high));
            self.sigmas.push(interval);
            self.weights.push(1.0);
            return;
        }
        // mus doubles as the sorted-observation buffer; NaN-safe ordering
        // keeps a poisoned observation from panicking the whole suggest
        self.mus.extend_from_slice(observations);
        self.mus.sort_unstable_by(crate::util::stats::nan_max_cmp);

        let sigma_max = interval;
        let sigma_min = interval / (1.0 + n as f64).min(100.0);
        for rank in 0..n {
            let mu = self.mus[rank];
            let left = if rank == 0 { low } else { self.mus[rank - 1] };
            let right = if rank + 1 == n { high } else { self.mus[rank + 1] };
            let bw = (mu - left).max(right - mu).clamp(sigma_min, sigma_max);
            self.sigmas.push(bw);
        }
        // prior component
        self.mus.push(0.5 * (low + high));
        self.sigmas.push(interval);
        self.weights.resize(n + 1, 1.0);
    }

    /// Number of mixture components.
    pub fn len(&self) -> usize {
        self.mus.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mus.is_empty()
    }

    /// Log-density at `x` (must mirror ref.py truncnorm_mixture_logpdf,
    /// including the EPS floors, so the PJRT kernel is interchangeable).
    pub fn logpdf(&self, x: f64) -> f64 {
        let wsum: f64 = self.weights.iter().sum::<f64>().max(EPS);
        let mut max_term = f64::NEG_INFINITY;
        let mut terms = Vec::with_capacity(self.len());
        for k in 0..self.len() {
            let w = self.weights[k];
            if w <= 0.0 {
                continue;
            }
            let mu = self.mus[k];
            let sg = self.sigmas[k];
            let z = (x - mu) / sg;
            let log_norm = -0.5 * z * z - sg.ln() - 0.5 * (2.0 * std::f64::consts::PI).ln();
            let a = (self.low - mu) / sg;
            let b = (self.high - mu) / sg;
            let mass = (ndtr(b) - ndtr(a)).max(EPS);
            let logw = (w / wsum).max(EPS).ln();
            let term = logw + log_norm - mass.ln();
            terms.push(term);
            if term > max_term {
                max_term = term;
            }
        }
        if terms.is_empty() {
            return f64::NEG_INFINITY;
        }
        let m = if max_term.is_finite() { max_term } else { 0.0 };
        let sum: f64 = terms.iter().map(|t| (t - m).exp()).sum();
        (sum + EPS).ln() + m
    }

    /// Sample one value from the truncated mixture.
    pub fn sample(&self, rng: &mut crate::util::rng::Pcg64) -> f64 {
        let k = rng.weighted_index(&self.weights);
        rng.trunc_normal(self.mus[k], self.sigmas[k], self.low, self.high)
    }

    /// Pad the mixture to `k_max` components as flat `f64` vectors in the
    /// layout batched scorers expect (dead components: weight 0, sigma 1).
    ///
    /// Kept in `f64` end to end: any consumer that truncated here (the
    /// old signature returned `f32`) could never be bit-equal to the
    /// scalar [`Self::logpdf`]. Backends with a genuinely 32-bit ABI
    /// (the PJRT Pallas kernel) convert at their literal boundary
    /// instead.
    pub fn to_kernel_inputs(&self, k_max: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        assert!(self.len() <= k_max, "mixture {} > kernel max {k_max}", self.len());
        let mut mus = vec![0.0f64; k_max];
        let mut sigmas = vec![1.0f64; k_max];
        let mut weights = vec![0.0f64; k_max];
        for i in 0..self.len() {
            mus[i] = self.mus[i];
            sigmas[i] = self.sigmas[i];
            weights[i] = self.weights[i];
        }
        (mus, sigmas, weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn empty_observations_is_prior_only() {
        let pe = ParzenEstimator::fit(&[], 0.0, 10.0);
        assert_eq!(pe.len(), 1);
        assert_eq!(pe.mus[0], 5.0);
        assert_eq!(pe.sigmas[0], 10.0);
    }

    #[test]
    fn component_count_is_n_plus_prior() {
        let pe = ParzenEstimator::fit(&[1.0, 2.0, 3.0], 0.0, 10.0);
        assert_eq!(pe.len(), 4);
    }

    #[test]
    fn density_integrates_to_one() {
        let pe = ParzenEstimator::fit(&[2.0, 2.5, 7.0], 0.0, 10.0);
        let n = 20_000;
        let h = 10.0 / n as f64;
        let integral: f64 = (0..=n)
            .map(|i| {
                let x = i as f64 * h;
                let w = if i == 0 || i == n { 0.5 } else { 1.0 };
                w * pe.logpdf(x).exp()
            })
            .sum::<f64>()
            * h;
        assert!((integral - 1.0).abs() < 1e-3, "integral={integral}");
    }

    #[test]
    fn density_peaks_near_observations() {
        let pe = ParzenEstimator::fit(&[3.0, 3.1, 2.9], 0.0, 10.0);
        assert!(pe.logpdf(3.0) > pe.logpdf(8.0));
        assert!(pe.logpdf(3.0) > pe.logpdf(0.5));
    }

    #[test]
    fn fit_into_reuse_matches_fresh_fit() {
        let mut reused = ParzenEstimator::default();
        // fit a large mixture first so the buffers carry stale capacity
        reused.fit_into(&(0..50).map(|i| i as f64 * 0.1).collect::<Vec<_>>(), -1.0, 6.0);
        for obs in [&[][..], &[2.0][..], &[2.0, 2.5, 7.0][..]] {
            reused.fit_into(obs, 0.0, 10.0);
            let fresh = ParzenEstimator::fit(obs, 0.0, 10.0);
            assert_eq!(reused.mus, fresh.mus);
            assert_eq!(reused.sigmas, fresh.sigmas);
            assert_eq!(reused.weights, fresh.weights);
            assert_eq!((reused.low, reused.high), (fresh.low, fresh.high));
        }
    }

    #[test]
    fn magic_clip_floors_bandwidth() {
        // duplicate observations would give zero bandwidth without the clip
        let pe = ParzenEstimator::fit(&[5.0, 5.0, 5.0], 0.0, 10.0);
        for (i, s) in pe.sigmas.iter().enumerate() {
            assert!(*s > 0.0, "sigma[{i}]={s}");
        }
        assert!(pe.logpdf(5.0).is_finite());
    }

    #[test]
    fn samples_respect_bounds() {
        let pe = ParzenEstimator::fit(&[1.0, 9.0], 0.0, 10.0);
        let mut rng = Pcg64::new(0);
        for _ in 0..2000 {
            let v = pe.sample(&mut rng);
            assert!((0.0..=10.0).contains(&v));
        }
    }

    #[test]
    fn kernel_padding_layout() {
        let pe = ParzenEstimator::fit(&[1.0, 2.0], 0.0, 4.0);
        let (mus, sigmas, weights) = pe.to_kernel_inputs(8);
        assert_eq!(mus.len(), 8);
        assert_eq!(weights[0..3], [1.0, 1.0, 1.0]);
        assert_eq!(weights[3..], [0.0; 5]);
        assert!(sigmas[4] == 1.0); // dead sigma placeholder positive
        // live components carry the exact f64 values — no f32 round-trip
        assert_eq!(mus[..3], pe.mus[..]);
        assert_eq!(sigmas[..3], pe.sigmas[..]);
    }

    #[test]
    #[should_panic]
    fn kernel_padding_overflow_panics() {
        let pe = ParzenEstimator::fit(&[1.0; 20], 0.0, 4.0);
        pe.to_kernel_inputs(8);
    }
}
