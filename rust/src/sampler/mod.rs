//! Samplers — the "searching strategy" half of §3.
//!
//! Optuna's sampler interface splits a trial's parameters into two groups
//! (§3.1):
//!
//! * **relative (relational) sampling** — before the objective runs, the
//!   sampler infers the search space that past trials have in *common*
//!   (the concurrence relations discoverable on a dynamically-constructed
//!   space) and may sample those parameters jointly (CMA-ES, GP).
//! * **independent sampling** — any parameter outside the relative space
//!   (first occurrences, conditional branches) is sampled on its own
//!   (random, TPE).
//!
//! Samplers are shared across worker threads, so implementations keep
//! their mutable state (RNG, CMA-ES evolution paths) behind a `Mutex`.

mod cmaes;
mod gp;
mod grid;
pub mod kernels;
mod parzen;
mod random;
mod rf;
mod search_space;
mod tpe;
mod tpe_cmaes;

pub use cmaes::CmaEsSampler;
pub use gp::GpSampler;
pub use grid::GridSampler;
pub use parzen::ParzenEstimator;
pub use random::RandomSampler;
pub use rf::RfSampler;
pub use search_space::{intersection_search_space, intersection_search_space_ctx};
pub use tpe::{CandidateScorer, ScoreGroup, TpeBackend, TpeConfig, TpeKernel, TpeSampler};
pub use tpe_cmaes::TpeCmaEsSampler;

use std::collections::BTreeMap;

use crate::core::{Distribution, FrozenTrial, IndexSnapshot, StudyDirection};

/// Read-only study context handed to samplers.
///
/// `trials` borrows the storage-layer snapshot taken once per `ask` (see
/// [`crate::storage::CachedStorage`]): every suggest within a trial — and
/// every concurrent worker on the same study generation — reads the same
/// immutable history, so sampler implementations should never fetch from
/// storage themselves.
pub struct StudyContext<'a> {
    pub direction: StudyDirection,
    /// Snapshot of all trials (any state), ordered by number.
    pub trials: &'a [FrozenTrial],
    /// Observation index synced to the same storage generation as
    /// `trials`, when the study maintains one (the default; see
    /// [`crate::core::ObservationIndex`]). Samplers read loss-sorted
    /// observation columns from it instead of re-scanning `trials`, and
    /// must fall back to scanning when it is `None`.
    pub index: Option<&'a IndexSnapshot>,
    /// Per-objective directions of a multi-objective study (`None` on a
    /// single-objective study — `direction` is authoritative there).
    /// Multi-objective samplers ([`crate::multi::NsgaIiSampler`]) read
    /// this; single-objective samplers ignore it and see objective 0
    /// through `direction`/`losses_of` as before.
    pub directions: Option<&'a [StudyDirection]>,
}

impl<'a> StudyContext<'a> {
    /// Context without an observation index (samplers scan `trials`).
    pub fn new(direction: StudyDirection, trials: &'a [FrozenTrial]) -> Self {
        StudyContext { direction, trials, index: None, directions: None }
    }

    /// Context backed by an observation index snapshot.
    pub fn with_index(
        direction: StudyDirection,
        trials: &'a [FrozenTrial],
        index: Option<&'a IndexSnapshot>,
    ) -> Self {
        StudyContext { direction, trials, index, directions: None }
    }

    /// Attach the study's full direction vector (multi-objective studies;
    /// builder-style so existing construction sites stay untouched).
    pub fn with_directions(mut self, directions: &'a [StudyDirection]) -> Self {
        if directions.len() > 1 {
            self.directions = Some(directions);
        }
        self
    }

    /// The per-objective directions: the full vector on a multi-objective
    /// study, else `direction` as a 1-slice.
    pub fn directions(&self) -> &[StudyDirection] {
        match self.directions {
            Some(ds) => ds,
            None => std::slice::from_ref(&self.direction),
        }
    }
    /// Completed trials only (what most samplers learn from).
    pub fn complete(&self) -> impl Iterator<Item = &'a FrozenTrial> + '_ {
        self.trials
            .iter()
            .filter(|t| t.state == crate::core::TrialState::Complete && t.value.is_some())
    }

    /// Objective values converted to minimization sign.
    pub fn losses_of(&self, trials: &[&'a FrozenTrial]) -> Vec<f64> {
        let sign = self.direction.min_sign();
        trials.iter().map(|t| sign * t.value.unwrap()).collect()
    }
}

/// Search-space map used by relative sampling (BTreeMap: deterministic
/// iteration order).
pub type SearchSpace = BTreeMap<String, Distribution>;

/// The sampling strategy interface (mirrors Optuna's `BaseSampler`).
pub trait Sampler: Send + Sync {
    /// Infer the sub-space eligible for joint (relational) sampling.
    /// Returning an empty map opts out of relative sampling entirely.
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace;

    /// Jointly sample every parameter of `space`; keyed by name, values are
    /// *internal* representations. Called once per trial, before the
    /// objective runs.
    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64>;

    /// Sample a single parameter outside the relative space. Called from
    /// inside `suggest_*` during the objective.
    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64;

    /// Human-readable name (logs, dashboards, benches).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Helpers shared by sampler unit tests.

    use super::*;
    use crate::core::{ParamValue, TrialState};

    /// Build a completed FrozenTrial from (name, dist, external value) plus
    /// an objective value.
    pub fn completed_trial(
        number: u64,
        params: &[(&str, Distribution, ParamValue)],
        value: f64,
    ) -> FrozenTrial {
        let mut t = FrozenTrial::new(number, number);
        for (name, dist, val) in params {
            let internal = dist.internal(val).unwrap();
            t.params.insert(name.to_string(), (dist.clone(), internal));
        }
        t.state = TrialState::Complete;
        t.value = Some(value);
        t
    }

    /// Quadratic-bowl history: x in [-5, 5], loss = x².
    pub fn bowl_history(n: usize, seed: u64) -> Vec<FrozenTrial> {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        (0..n)
            .map(|i| {
                let x = rng.uniform_range(-5.0, 5.0);
                completed_trial(
                    i as u64,
                    &[("x", Distribution::float(-5.0, 5.0), ParamValue::Float(x))],
                    x * x,
                )
            })
            .collect()
    }
}
