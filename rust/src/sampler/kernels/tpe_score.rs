//! Batched TPE acquisition scoring: `log l(x) − log g(x)` over a whole
//! candidate grid in one pass, bit-identical to the scalar
//! [`ParzenEstimator::logpdf`] oracle.
//!
//! ## Why this is fast
//!
//! The scalar path evaluates, *per candidate*, the full per-component
//! pipeline: truncation mass (two `erf` calls), `sigma.ln()`,
//! `(w/Σw).ln()` — none of which depend on the candidate. With 24
//! candidates × 64 components × 2 mixtures that is ~3000 `erf`+`ln`
//! evaluations per suggest, of which ~2900 recompute known values.
//! [`MixtureKernel::compile_from`] hoists all candidate-invariant work
//! into flat per-component arrays once per suggest; the remaining
//! per-(candidate, component) work is a handful of flops —
//! `z = (x−µ)/σ; t = logw + (−0.5z² − lnσ − ½ln2π) − ln mass` — laid out
//! as chunked, branch-free loops over contiguous arrays that LLVM
//! autovectorizes (f64x4 on AVX2).
//!
//! ## Why it is bit-identical
//!
//! Hoisting loop invariants does not change a single float operation:
//! every candidate still computes `logw + log_norm − mass_ln` with the
//! exact operand values and association order of the scalar code, the
//! logsumexp max is tracked with the same `term > max` comparison, and
//! the exp-sum accumulates in the same component order (the terms buffer
//! is component-major per candidate chunk). Dead components (`w ≤ 0`)
//! are filtered at compile time exactly where the scalar loop `continue`s,
//! and the weight normalizer Σw sums *all* weights first, dead ones
//! included, just like the scalar oracle. `rust/tests/kernel_equiv.rs`
//! and the property tests below assert `to_bits()` equality.

use crate::sampler::parzen::{ndtr, ParzenEstimator, EPS};

/// Candidate-chunk width. Eight f64 lanes = two AVX2 vectors or one
/// AVX-512 vector per operation; the arrays below are tiny (≤ a few KiB)
/// so the only consideration is giving LLVM a full unrollable lane loop.
pub const LANES: usize = 8;

/// A [`ParzenEstimator`] compiled for batched scoring: live components
/// only (scalar `logpdf` skips `w ≤ 0`), as flat structure-of-arrays
/// columns of the per-component constants the per-candidate loop needs.
///
/// `compile_from` reuses the buffers, so a warm [`MixtureKernel`]
/// allocates nothing per suggest.
#[derive(Debug, Clone, Default)]
pub struct MixtureKernel {
    mu: Vec<f64>,
    sigma: Vec<f64>,
    /// `ln((w/Σw).max(EPS))` — Σw over *all* weights, dead included.
    logw: Vec<f64>,
    /// `σ.ln()`, hoisted out of `log_norm`.
    sigma_ln: Vec<f64>,
    /// `ln((ndtr(b) − ndtr(a)).max(EPS))` — the truncation mass.
    mass_ln: Vec<f64>,
}

impl MixtureKernel {
    /// Number of live components.
    pub fn len(&self) -> usize {
        self.mu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mu.is_empty()
    }

    /// Hoist all candidate-invariant per-component work out of `pe`.
    /// Every value is produced by the identical expression the scalar
    /// `logpdf` evaluates per candidate, so reusing them cannot perturb
    /// a bit.
    pub fn compile_from(&mut self, pe: &ParzenEstimator) {
        self.mu.clear();
        self.sigma.clear();
        self.logw.clear();
        self.sigma_ln.clear();
        self.mass_ln.clear();
        let wsum: f64 = pe.weights.iter().sum::<f64>().max(EPS);
        for k in 0..pe.len() {
            let w = pe.weights[k];
            if w <= 0.0 {
                continue; // dead component — scalar logpdf skips it too
            }
            let mu = pe.mus[k];
            let sg = pe.sigmas[k];
            let a = (pe.low - mu) / sg;
            let b = (pe.high - mu) / sg;
            let mass = (ndtr(b) - ndtr(a)).max(EPS);
            self.mu.push(mu);
            self.sigma.push(sg);
            self.logw.push((w / wsum).max(EPS).ln());
            self.sigma_ln.push(sg.ln());
            self.mass_ln.push(mass.ln());
        }
    }
}

/// Reusable intermediate buffers for [`score_into`] / [`logpdf_into`].
#[derive(Debug, Default)]
pub struct KernelScratch {
    /// Component-major terms for one candidate chunk: `terms[k*LANES+l]`.
    terms: Vec<f64>,
    below_pdf: Vec<f64>,
    above_pdf: Vec<f64>,
}

/// TPE acquisition for every candidate: `out[i] = log l(c_i) − log g(c_i)`
/// with both log-densities bit-identical to the scalar oracle.
pub fn score_into(
    cand: &[f64],
    below: &MixtureKernel,
    above: &MixtureKernel,
    scratch: &mut KernelScratch,
    out: &mut Vec<f64>,
) {
    let KernelScratch { terms, below_pdf, above_pdf } = scratch;
    logpdf_into(below, cand, terms, below_pdf);
    logpdf_into(above, cand, terms, above_pdf);
    out.clear();
    out.extend(below_pdf.iter().zip(above_pdf.iter()).map(|(l, g)| l - g));
}

/// Batched truncated-mixture log-density: `out[i] = logpdf(xs[i])`,
/// bit-for-bit equal to [`ParzenEstimator::logpdf`] on the estimator
/// `mk` was compiled from.
///
/// Two passes per chunk of [`LANES`] candidates: pass 1 fills a
/// component-major terms matrix and tracks the per-candidate running max
/// (the vectorizable flop loop); pass 2 is the logsumexp reduction in
/// the scalar component order.
pub fn logpdf_into(mk: &MixtureKernel, xs: &[f64], terms: &mut Vec<f64>, out: &mut Vec<f64>) {
    out.clear();
    let kc = mk.len();
    if kc == 0 {
        // all components dead: scalar logpdf returns −∞
        out.resize(xs.len(), f64::NEG_INFINITY);
        return;
    }
    terms.clear();
    terms.resize(kc * LANES, 0.0);
    let mut chunks = xs.chunks_exact(LANES);
    for chunk in chunks.by_ref() {
        let mut maxt = [f64::NEG_INFINITY; LANES];
        fill_terms(mk, chunk.try_into().expect("chunks_exact"), terms, &mut maxt);
        reduce_logsumexp(kc, terms, &maxt, LANES, out);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut padded = [0.0f64; LANES];
        padded[..rem.len()].copy_from_slice(rem);
        let mut maxt = [f64::NEG_INFINITY; LANES];
        fill_terms(mk, &padded, terms, &mut maxt);
        reduce_logsumexp(kc, terms, &maxt, rem.len(), out);
    }
}

/// Pass 1: per-(component, lane) term + running per-lane max. The lane
/// loop is branch-free over fixed-width arrays — the autovectorization
/// target. `term` uses the scalar oracle's exact expression shape:
/// `logw + (−0.5z² − lnσ − ½ln2π) − ln mass`, left-associated.
#[cfg(not(feature = "simd"))]
fn fill_terms(mk: &MixtureKernel, chunk: &[f64; LANES], terms: &mut [f64], maxt: &mut [f64; LANES]) {
    let half_ln_2pi = 0.5 * (2.0 * std::f64::consts::PI).ln();
    for k in 0..mk.len() {
        let mu = mk.mu[k];
        let sg = mk.sigma[k];
        let sg_ln = mk.sigma_ln[k];
        let logw = mk.logw[k];
        let mass_ln = mk.mass_ln[k];
        let row = &mut terms[k * LANES..(k + 1) * LANES];
        for l in 0..LANES {
            let z = (chunk[l] - mu) / sg;
            let log_norm = -0.5 * z * z - sg_ln - half_ln_2pi;
            let term = logw + log_norm - mass_ln;
            row[l] = term;
            // same semantics as the scalar `if term > max` (NaN keeps max)
            maxt[l] = if term > maxt[l] { term } else { maxt[l] };
        }
    }
}

/// Pass 1 with explicit `std::simd` lanes (nightly, `--features simd`).
/// Only exactly-rounded IEEE ops (sub/div/mul/add, compare-select) run
/// vectorized, so the result stays bit-identical to the autovec path.
#[cfg(feature = "simd")]
fn fill_terms(mk: &MixtureKernel, chunk: &[f64; LANES], terms: &mut [f64], maxt: &mut [f64; LANES]) {
    use std::simd::cmp::SimdPartialOrd;
    use std::simd::f64x8;
    let half_ln_2pi = f64x8::splat(0.5 * (2.0 * std::f64::consts::PI).ln());
    let x = f64x8::from_array(*chunk);
    let mut m = f64x8::from_array(*maxt);
    for k in 0..mk.len() {
        let mu = f64x8::splat(mk.mu[k]);
        let sg = f64x8::splat(mk.sigma[k]);
        let sg_ln = f64x8::splat(mk.sigma_ln[k]);
        let logw = f64x8::splat(mk.logw[k]);
        let mass_ln = f64x8::splat(mk.mass_ln[k]);
        let z = (x - mu) / sg;
        let log_norm = f64x8::splat(-0.5) * z * z - sg_ln - half_ln_2pi;
        let term = logw + log_norm - mass_ln;
        terms[k * LANES..(k + 1) * LANES].copy_from_slice(term.as_array());
        m = term.simd_gt(m).select(term, m);
    }
    *maxt = *m.as_array();
}

/// Pass 2: logsumexp over the component axis for the first `n_live`
/// lanes, in the scalar oracle's component order and with its exact
/// finiteness fallback (`m = 0` when the max is ±∞/NaN).
fn reduce_logsumexp(kc: usize, terms: &[f64], maxt: &[f64; LANES], n_live: usize, out: &mut Vec<f64>) {
    for l in 0..n_live {
        let m = if maxt[l].is_finite() { maxt[l] } else { 0.0 };
        let mut sum = 0.0f64;
        for k in 0..kc {
            sum += (terms[k * LANES + l] - m).exp();
        }
        out.push((sum + EPS).ln() + m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::quickcheck::check;
    use crate::util::rng::Pcg64;

    /// A random but well-formed estimator: fitted to random observations,
    /// then (sometimes) perturbed with dead / extreme weights.
    fn random_estimator(rng: &mut Pcg64) -> ParzenEstimator {
        let lo = rng.uniform_range(-10.0, 0.0);
        let hi = lo + rng.uniform_range(0.5, 20.0);
        let n = rng.index(24);
        let obs: Vec<f64> = (0..n).map(|_| rng.uniform_range(lo, hi)).collect();
        let mut pe = ParzenEstimator::fit(&obs, lo, hi);
        // perturb weights: scalar logpdf must keep agreeing through the
        // dead-component filter and the all-weights normalizer
        for w in pe.weights.iter_mut() {
            match rng.index(8) {
                0 => *w = 0.0,
                1 => *w = -1.0,
                2 => *w = rng.uniform_range(0.0, 100.0),
                _ => {}
            }
        }
        pe
    }

    #[test]
    fn batched_logpdf_is_bit_identical_to_scalar() {
        check("kernels::logpdf_bits", 300, |rng| {
            let pe = random_estimator(rng);
            let mut mk = MixtureKernel::default();
            mk.compile_from(&pe);
            let n = rng.index(40); // covers empty, sub-chunk, multi-chunk
            let xs: Vec<f64> = (0..n)
                .map(|_| rng.uniform_range(pe.low - 1.0, pe.high + 1.0))
                .collect();
            let (mut terms, mut out) = (Vec::new(), Vec::new());
            logpdf_into(&mk, &xs, &mut terms, &mut out);
            prop_assert!(out.len() == xs.len(), "length mismatch");
            for (i, &x) in xs.iter().enumerate() {
                let want = pe.logpdf(x);
                prop_assert!(
                    out[i].to_bits() == want.to_bits(),
                    "logpdf({x}) kernel={} scalar={want}",
                    out[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batched_score_is_bit_identical_to_scalar_difference() {
        check("kernels::tpe_score_bits", 300, |rng| {
            let below = random_estimator(rng);
            let above = random_estimator(rng);
            let (mut bk, mut ak) = (MixtureKernel::default(), MixtureKernel::default());
            bk.compile_from(&below);
            ak.compile_from(&above);
            let n = 1 + rng.index(30);
            let xs: Vec<f64> = (0..n).map(|_| rng.uniform_range(-12.0, 12.0)).collect();
            let mut scratch = KernelScratch::default();
            let mut out = Vec::new();
            score_into(&xs, &bk, &ak, &mut scratch, &mut out);
            for (i, &x) in xs.iter().enumerate() {
                let want = below.logpdf(x) - above.logpdf(x);
                // NaN == NaN here: compare representations, not values
                prop_assert!(
                    out[i].to_bits() == want.to_bits(),
                    "score({x}) kernel={} scalar={want}",
                    out[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn all_dead_mixture_scores_neg_infinity() {
        let mut pe = ParzenEstimator::fit(&[1.0, 2.0], 0.0, 4.0);
        for w in pe.weights.iter_mut() {
            *w = 0.0;
        }
        let mut mk = MixtureKernel::default();
        mk.compile_from(&pe);
        assert!(mk.is_empty());
        let (mut terms, mut out) = (Vec::new(), Vec::new());
        logpdf_into(&mk, &[0.5, 3.0], &mut terms, &mut out);
        assert_eq!(out, vec![f64::NEG_INFINITY; 2]);
        // and the scalar oracle agrees
        assert_eq!(pe.logpdf(0.5), f64::NEG_INFINITY);
    }

    #[test]
    fn compile_reuse_matches_fresh_compile() {
        let mut rng = Pcg64::new(99);
        let mut reused = MixtureKernel::default();
        for _ in 0..20 {
            let pe = random_estimator(&mut rng);
            reused.compile_from(&pe);
            let mut fresh = MixtureKernel::default();
            fresh.compile_from(&pe);
            assert_eq!(reused.mu, fresh.mu);
            assert_eq!(reused.logw, fresh.logw);
            assert_eq!(reused.mass_ln, fresh.mass_ln);
        }
    }

    #[test]
    fn nan_candidate_matches_scalar() {
        let pe = ParzenEstimator::fit(&[1.0, 2.0, 3.0], 0.0, 4.0);
        let mut mk = MixtureKernel::default();
        mk.compile_from(&pe);
        let (mut terms, mut out) = (Vec::new(), Vec::new());
        logpdf_into(&mk, &[f64::NAN, 2.0], &mut terms, &mut out);
        assert_eq!(out[0].to_bits(), pe.logpdf(f64::NAN).to_bits());
        assert_eq!(out[1].to_bits(), pe.logpdf(2.0).to_bits());
    }
}
