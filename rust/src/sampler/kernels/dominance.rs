//! Branch-free Pareto dominance over flat key columns, plus bit-packed
//! Deb front peeling — the vectorized twin of `multi/nds.rs` and the
//! `multi/hypervolume.rs` filter loop.
//!
//! ## The key embedding
//!
//! [`nan_max_cmp`] defines a total order on `f64` (NaN greatest, equal
//! NaNs equal, `−0.0 == 0.0`). [`loss_key`] embeds that order into `u64`
//! monotonically, so every per-objective comparison in a dominance check
//! becomes one unsigned integer compare — no NaN branch, no
//! `partial_cmp` `Option`, no `Ordering` match. A dominance test over m
//! objectives is then `all(kaᵢ ≤ kbᵢ) && any(kaᵢ < kbᵢ)` over contiguous
//! `u64` rows: exactly the shape LLVM turns into SIMD compares.
//!
//! ## Equivalence with the scalar oracle
//!
//! The scalar `sort_by_dominance` is pure index bookkeeping once the
//! dominance relation is fixed: its `dominated[i]` lists are built in
//! ascending index order and its fronts peel in ascending order. The
//! bit-packed peeling below iterates set bits ascending, so it replays
//! the identical decision sequence — `rust/tests/kernel_equiv.rs` and
//! the tests below assert front-for-front equality (same nesting, same
//! order) against `nondominated_sort_scalar`.
//!
//! Ragged inputs (rows of unequal length) have no flat layout; callers
//! fall back to the scalar path when [`FlatKeys::from_rows`] declines.

use crate::util::stats::nan_max_cmp;

/// Monotone embedding of [`nan_max_cmp`]'s total order into `u64`:
/// `loss_key(a) < loss_key(b) ⟺ nan_max_cmp(a, b) == Less`, and equal
/// keys exactly where the comparator says `Equal` (`−0.0` canonicalizes
/// to `+0.0`; every NaN maps to `u64::MAX`, above `+∞`).
#[inline]
pub fn loss_key(x: f64) -> u64 {
    if x.is_nan() {
        return u64::MAX;
    }
    let x = if x == 0.0 { 0.0 } else { x }; // −0.0 → +0.0
    let b = x.to_bits();
    if b >> 63 == 1 {
        !b // negative: reverse magnitude order below the positives
    } else {
        b | (1u64 << 63) // non-negative: shift above every negative
    }
}

/// A rectangular loss matrix as one flat row-major `u64` key array.
#[derive(Debug, Clone)]
pub struct FlatKeys {
    keys: Vec<u64>,
    n: usize,
    m: usize,
}

impl FlatKeys {
    /// Flatten `rows`; `None` when the rows disagree on length (no
    /// rectangular layout — callers keep the scalar path).
    pub fn from_rows(rows: &[Vec<f64>]) -> Option<FlatKeys> {
        Self::build(rows.len(), rows.first().map_or(0, |r| r.len()), |i| &rows[i])
    }

    /// [`Self::from_rows`] over borrowed slices.
    pub fn from_slices(rows: &[&[f64]]) -> Option<FlatKeys> {
        Self::build(rows.len(), rows.first().map_or(0, |r| r.len()), |i| rows[i])
    }

    fn build<'a>(n: usize, m: usize, row: impl Fn(usize) -> &'a [f64]) -> Option<FlatKeys> {
        let mut keys = Vec::with_capacity(n * m);
        for i in 0..n {
            let r = row(i);
            if r.len() != m {
                return None;
            }
            keys.extend(r.iter().map(|&x| loss_key(x)));
        }
        Some(FlatKeys { keys, n, m })
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    fn row(&self, i: usize) -> &[u64] {
        &self.keys[i * self.m..(i + 1) * self.m]
    }
}

/// `(a dominates b, b dominates a)` in one pass over the key rows.
/// Width-specialized for the common m = 2 and m = 3 so the compare chain
/// is a handful of scalar ops with no loop at all.
#[inline]
fn pareto_pair(a: &[u64], b: &[u64]) -> (bool, bool) {
    let (a_lt, a_gt) = match a.len() {
        2 => (a[0] < b[0] || a[1] < b[1], a[0] > b[0] || a[1] > b[1]),
        3 => (
            a[0] < b[0] || a[1] < b[1] || a[2] < b[2],
            a[0] > b[0] || a[1] > b[1] || a[2] > b[2],
        ),
        _ => {
            let (mut lt, mut gt) = (false, false);
            for (x, y) in a.iter().zip(b) {
                lt |= x < y;
                gt |= x > y;
            }
            (lt, gt)
        }
    };
    (a_lt && !a_gt, a_gt && !a_lt)
}

/// `(a dom b, b dom a)` under Deb's constrained rules — the key-space
/// twin of `dominates_constrained` (violations compare with plain `<`,
/// so a NaN violation neither dominates nor is "smaller").
#[inline]
fn constrained_pair(a: &[u64], b: &[u64], va: f64, vb: f64) -> (bool, bool) {
    match (va <= 0.0, vb <= 0.0) {
        (true, false) => (true, false),
        (false, true) => (false, true),
        (false, false) => (va < vb, vb < va),
        (true, true) => pareto_pair(a, b),
    }
}

/// Deb front peeling over an n×n bit-packed dominance matrix. With
/// `violations`, pairs compare under constrained dominance. Produces
/// exactly what the scalar `sort_by_dominance` produces — same fronts,
/// same within-front order.
pub fn sort_fronts(flat: &FlatKeys, violations: Option<&[f64]>) -> Vec<Vec<usize>> {
    let n = flat.n;
    if n == 0 {
        return Vec::new();
    }
    let words = (n + 63) / 64;
    // dominated[i*words..] = bitset of indices i dominates
    let mut dominated = vec![0u64; n * words];
    let mut count = vec![0usize; n];
    for i in 0..n {
        let ri = flat.row(i);
        for j in (i + 1)..n {
            let (dij, dji) = match violations {
                None => pareto_pair(ri, flat.row(j)),
                Some(v) => constrained_pair(ri, flat.row(j), v[i], v[j]),
            };
            if dij {
                dominated[i * words + j / 64] |= 1u64 << (j % 64);
                count[j] += 1;
            } else if dji {
                dominated[j * words + i / 64] |= 1u64 << (i % 64);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            // ascending set-bit walk == the scalar dominated[i] list order
            for (w, &word) in dominated[i * words..(i + 1) * words].iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let j = w * 64 + bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    count[j] -= 1;
                    if count[j] == 0 {
                        next.push(j);
                    }
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Indices of the mutually-nondominated, duplicate-free subset, in input
/// order — the key-space twin of the hypervolume sweep's
/// `pareto_filter` (which compares with [`nan_max_cmp`] per objective).
pub fn pareto_filter_indices(flat: &FlatKeys) -> Vec<usize> {
    let n = flat.n;
    let mut kept: Vec<usize> = Vec::with_capacity(n);
    'outer: for p in 0..n {
        let rp = flat.row(p);
        for q in 0..n {
            if q != p && pareto_pair(flat.row(q), rp).0 {
                continue 'outer;
            }
        }
        if kept.iter().any(|&k| flat.row(k) == rp) {
            continue; // exact duplicate (key-equal ⟺ nan_max-equal) already kept
        }
        kept.push(p);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multi::dominance::{dominates, dominates_constrained};
    use crate::prop_assert;
    use crate::util::quickcheck::check;
    use crate::util::rng::Pcg64;
    use std::cmp::Ordering;

    fn weird_value(rng: &mut Pcg64) -> f64 {
        match rng.index(10) {
            0 => f64::NAN,
            1 => f64::INFINITY,
            2 => f64::NEG_INFINITY,
            3 => -0.0,
            4 => 0.0,
            5..=7 => rng.int_range(-3, 3) as f64, // coarse grid: ties abound
            _ => rng.uniform_range(-100.0, 100.0),
        }
    }

    #[test]
    fn key_embedding_preserves_total_order() {
        check("kernels::loss_key_order", 400, |rng| {
            let a = weird_value(rng);
            let b = weird_value(rng);
            let want = nan_max_cmp(&a, &b);
            let got = loss_key(a).cmp(&loss_key(b));
            prop_assert!(got == want, "key order for ({a}, {b}): {got:?} vs {want:?}");
            Ok(())
        });
    }

    #[test]
    fn pareto_pair_matches_scalar_dominates() {
        check("kernels::pareto_pair", 300, |rng| {
            let m = 1 + rng.index(5);
            let a: Vec<f64> = (0..m).map(|_| weird_value(rng)).collect();
            let b: Vec<f64> = (0..m).map(|_| weird_value(rng)).collect();
            let flat = FlatKeys::from_rows(&[a.clone(), b.clone()]).unwrap();
            let (dab, dba) = pareto_pair(flat.row(0), flat.row(1));
            prop_assert!(
                dab == dominates(&a, &b) && dba == dominates(&b, &a),
                "pair mismatch a={a:?} b={b:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn constrained_pair_matches_scalar() {
        check("kernels::constrained_pair", 300, |rng| {
            let m = 1 + rng.index(3);
            let a: Vec<f64> = (0..m).map(|_| weird_value(rng)).collect();
            let b: Vec<f64> = (0..m).map(|_| weird_value(rng)).collect();
            let viol = |rng: &mut Pcg64| match rng.index(4) {
                0 => 0.0,
                1 => f64::NAN,
                _ => rng.uniform_range(0.0, 2.0),
            };
            let (va, vb) = (viol(rng), viol(rng));
            let flat = FlatKeys::from_rows(&[a.clone(), b.clone()]).unwrap();
            let (dab, dba) = constrained_pair(flat.row(0), flat.row(1), va, vb);
            prop_assert!(
                dab == dominates_constrained(&a, va, &b, vb)
                    && dba == dominates_constrained(&b, vb, &a, va),
                "constrained pair mismatch a={a:?}({va}) b={b:?}({vb})"
            );
            Ok(())
        });
    }

    #[test]
    fn ragged_rows_decline_flat_layout() {
        assert!(FlatKeys::from_rows(&[vec![1.0, 2.0], vec![1.0]]).is_none());
        assert!(FlatKeys::from_rows(&[]).unwrap().is_empty());
    }

    #[test]
    fn filter_keeps_order_and_drops_duplicates() {
        let rows = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![3.0, 3.0], // dominated by (2,2)
            vec![1.0, 4.0], // duplicate of row 0
            vec![4.0, 1.0],
        ];
        let flat = FlatKeys::from_rows(&rows).unwrap();
        assert_eq!(pareto_filter_indices(&flat), vec![0, 1, 4]);
    }

    #[test]
    fn duplicate_keys_compare_equal_through_nan_and_signed_zero() {
        let flat =
            FlatKeys::from_rows(&[vec![f64::NAN, -0.0], vec![f64::NAN, 0.0]]).unwrap();
        assert_eq!(flat.row(0), flat.row(1));
        assert_eq!(nan_max_cmp(&-0.0, &0.0), Ordering::Equal);
    }
}
