//! Vectorizable scoring/sorting kernels over flat structure-of-arrays
//! data (ISSUE 10).
//!
//! The observation index (`core/obs_index.rs`) already hands the
//! samplers loss-sorted SoA columns; these kernels are the matching
//! compute layer: chunked, branch-free inner loops over contiguous
//! arrays that LLVM autovectorizes, with every float operation kept in
//! the scalar oracle's exact order so the results are **bit-identical**
//! — the scalar paths stay alive as differential oracles (the
//! `SingleMutexStorage` pattern from the storage layer), asserted by
//! `rust/tests/kernel_equiv.rs` and the per-module property tests.
//!
//! * [`tpe_score`] — batched TPE acquisition (`log l − log g`) over a
//!   candidate grid, selected per sampler via the `tpe:kernel=…` registry
//!   knob ([`crate::sampler::TpeKernel`]).
//! * [`dominance`] — `u64`-key Pareto dominance, bit-packed Deb front
//!   peeling, and the hypervolume sweep's nondominated filter.
//!
//! An opt-in `std::simd` path (`--features simd`, nightly) replaces the
//! autovectorized TPE lane loop with explicit `f64x8` ops; only
//! exactly-rounded IEEE arithmetic runs in SIMD registers, so the
//! feature changes codegen, never results.

pub mod dominance;
pub mod tpe_score;

pub use tpe_score::{score_into, KernelScratch, MixtureKernel, LANES};
