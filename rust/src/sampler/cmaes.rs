//! CMA-ES relational sampler (Hansen & Ostermeier 2001).
//!
//! The paper's §3.1 relational-sampling example: once the intersection
//! search space has been inferred from completed trials, CMA-ES models
//! the joint distribution of the numeric parameters (normalized to the
//! unit cube) with full covariance adaptation — rank-1 + rank-μ updates,
//! cumulative step-size adaptation, and an eigendecomposition from
//! `util::linalg::eigh`.
//!
//! Ask/tell bookkeeping: every relative sample is an "ask" remembered by
//! trial number; completed trials matching outstanding asks are fed back
//! as a generation once λ results are in. Categorical and out-of-space
//! parameters fall back to independent sampling (random by default —
//! mirroring Optuna's `CmaEsSampler`).

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::core::{Distribution, TrialState};
use crate::sampler::random::RandomSampler;
use crate::sampler::search_space::{intersection_search_space_ctx, trial_coords};
use crate::sampler::{Sampler, SearchSpace, StudyContext};
use crate::util::linalg::{eigh, Mat};
use crate::util::rng::Pcg64;

/// Core CMA-ES state over the unit cube [0,1]^d.
struct CmaState {
    dim: usize,
    lambda: usize,
    mu: usize,
    weights: Vec<f64>,
    mu_eff: f64,
    c_c: f64,
    c_sigma: f64,
    c_1: f64,
    c_mu: f64,
    d_sigma: f64,
    chi_n: f64,
    mean: Vec<f64>,
    sigma: f64,
    cov: Mat,
    p_c: Vec<f64>,
    p_sigma: Vec<f64>,
    /// eigendecomposition cache of cov: C = B diag(d²) Bᵀ
    eig_b: Mat,
    eig_d: Vec<f64>,
    generation: u64,
    /// outstanding asks: trial number → y (the N(0,C) draw, pre-sigma)
    asked: HashMap<u64, Vec<f64>>,
    /// completed (loss, y) pairs waiting for a generation update
    told: Vec<(f64, Vec<f64>)>,
    /// highest trial number already consumed into `told`
    consumed_through: i64,
    /// identity of the space this state was built for
    space_key: String,
}

impl CmaState {
    fn new(dim: usize, mean: Vec<f64>, sigma: f64) -> CmaState {
        let lambda = 4 + (3.0 * (dim as f64).ln()).floor() as usize;
        let mu = lambda / 2;
        // log-rank weights
        let raw: Vec<f64> = (0..mu)
            .map(|i| ((mu as f64 + 0.5).ln() - ((i + 1) as f64).ln()).max(0.0))
            .collect();
        let wsum: f64 = raw.iter().sum();
        let weights: Vec<f64> = raw.iter().map(|w| w / wsum).collect();
        let mu_eff = 1.0 / weights.iter().map(|w| w * w).sum::<f64>();
        let n = dim as f64;
        let c_sigma = (mu_eff + 2.0) / (n + mu_eff + 5.0);
        let d_sigma = 1.0
            + 2.0 * (0.0f64).max(((mu_eff - 1.0) / (n + 1.0)).sqrt() - 1.0)
            + c_sigma;
        let c_c = (4.0 + mu_eff / n) / (n + 4.0 + 2.0 * mu_eff / n);
        let c_1 = 2.0 / ((n + 1.3) * (n + 1.3) + mu_eff);
        let c_mu = (1.0 - c_1).min(
            2.0 * (mu_eff - 2.0 + 1.0 / mu_eff) / ((n + 2.0) * (n + 2.0) + mu_eff),
        );
        let chi_n = n.sqrt() * (1.0 - 1.0 / (4.0 * n) + 1.0 / (21.0 * n * n));
        CmaState {
            dim,
            lambda,
            mu,
            weights,
            mu_eff,
            c_c,
            c_sigma,
            c_1,
            c_mu,
            d_sigma,
            chi_n,
            mean,
            sigma,
            cov: Mat::eye(dim),
            p_c: vec![0.0; dim],
            p_sigma: vec![0.0; dim],
            eig_b: Mat::eye(dim),
            eig_d: vec![1.0; dim],
            generation: 0,
            asked: HashMap::new(),
            told: Vec::new(),
            consumed_through: -1,
            space_key: String::new(),
        }
    }

    fn refresh_eig(&mut self) {
        let (vals, vecs) = eigh(&self.cov);
        self.eig_d = vals.iter().map(|v| v.max(1e-20).sqrt()).collect();
        self.eig_b = vecs;
    }

    /// Draw y ~ N(0, C); x = mean + sigma·y clipped to the unit cube.
    fn ask(&mut self, rng: &mut Pcg64, trial_number: u64) -> Vec<f64> {
        let z: Vec<f64> = (0..self.dim).map(|_| rng.normal()).collect();
        // y = B (D .* z)
        let dz: Vec<f64> = z.iter().zip(&self.eig_d).map(|(zi, di)| zi * di).collect();
        let y = self.eig_b.matvec(&dz);
        self.asked.insert(trial_number, y.clone());
        y.iter()
            .zip(&self.mean)
            .map(|(yi, mi)| (mi + self.sigma * yi).clamp(0.0, 1.0))
            .collect()
    }

    /// One generation update from the best-μ of λ told solutions.
    fn update(&mut self) {
        // NaN-safe: a diverged (NaN) objective ranks worst, not a panic
        self.told
            .sort_by(|a, b| crate::util::stats::nan_max_cmp(&a.0, &b.0));
        let ys: Vec<&Vec<f64>> = self.told.iter().take(self.mu).map(|(_, y)| y).collect();
        let n = self.dim;
        // weighted mean step  y_w
        let mut y_w = vec![0.0; n];
        for (w, y) in self.weights.iter().zip(&ys) {
            for i in 0..n {
                y_w[i] += w * y[i];
            }
        }
        // mean update
        for i in 0..n {
            self.mean[i] = (self.mean[i] + self.sigma * y_w[i]).clamp(0.0, 1.0);
        }
        // p_sigma: C^{-1/2} y_w = B diag(1/d) Bᵀ y_w
        let bt_yw = self.eig_b.t().matvec(&y_w);
        let scaled: Vec<f64> = bt_yw
            .iter()
            .zip(&self.eig_d)
            .map(|(v, d)| v / d.max(1e-20))
            .collect();
        let c_inv_sqrt_yw = self.eig_b.matvec(&scaled);
        let cs = self.c_sigma;
        let coef = (cs * (2.0 - cs) * self.mu_eff).sqrt();
        for i in 0..n {
            self.p_sigma[i] = (1.0 - cs) * self.p_sigma[i] + coef * c_inv_sqrt_yw[i];
        }
        let p_sigma_norm = self.p_sigma.iter().map(|v| v * v).sum::<f64>().sqrt();
        // step-size
        self.sigma *= ((cs / self.d_sigma) * (p_sigma_norm / self.chi_n - 1.0)).exp();
        self.sigma = self.sigma.clamp(1e-8, 1.0);
        // h_sigma (stall indicator)
        let gen1 = self.generation as f64 + 1.0;
        let h_sigma = if p_sigma_norm
            / (1.0 - (1.0 - cs).powf(2.0 * gen1)).sqrt()
            < (1.4 + 2.0 / (n as f64 + 1.0)) * self.chi_n
        {
            1.0
        } else {
            0.0
        };
        // p_c
        let cc = self.c_c;
        let coef_c = (cc * (2.0 - cc) * self.mu_eff).sqrt();
        for i in 0..n {
            self.p_c[i] = (1.0 - cc) * self.p_c[i] + h_sigma * coef_c * y_w[i];
        }
        // covariance: rank-1 + rank-mu
        let delta_h = (1.0 - h_sigma) * cc * (2.0 - cc);
        let old_coef = 1.0 - self.c_1 - self.c_mu;
        let mut new_cov = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut v = old_coef * self.cov[(i, j)]
                    + self.c_1
                        * (self.p_c[i] * self.p_c[j] + delta_h * self.cov[(i, j)]);
                for (w, y) in self.weights.iter().zip(&ys) {
                    v += self.c_mu * w * y[i] * y[j];
                }
                new_cov[(i, j)] = v;
            }
        }
        // symmetrize (numerical)
        for i in 0..n {
            for j in 0..i {
                let avg = 0.5 * (new_cov[(i, j)] + new_cov[(j, i)]);
                new_cov[(i, j)] = avg;
                new_cov[(j, i)] = avg;
            }
        }
        self.cov = new_cov;
        self.refresh_eig();
        self.generation += 1;
        self.told.clear();
    }
}

/// The sampler (state behind a mutex; see module docs for the ask/tell
/// protocol).
pub struct CmaEsSampler {
    rng: Mutex<Pcg64>,
    state: Mutex<Option<CmaState>>,
    fallback: RandomSampler,
    /// Initial global step size on the unit cube.
    pub sigma0: f64,
    /// Trials before relational sampling kicks in.
    pub n_startup_trials: usize,
}

impl CmaEsSampler {
    pub fn new(seed: u64) -> Self {
        CmaEsSampler {
            rng: Mutex::new(Pcg64::new(seed)),
            state: Mutex::new(None),
            fallback: RandomSampler::new(seed ^ 0x5eed),
            sigma0: 0.25,
            n_startup_trials: 4,
        }
    }

    /// Registry constructor (spec `cmaes:sigma=0.5,n_startup=8`).
    pub fn from_config(
        cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        let mut s = CmaEsSampler::new(seed);
        if let Some(v) = cfg.get_f64("sigma")? {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("sigma must be positive and finite, got {v}"));
            }
            s.sigma0 = v;
        }
        if let Some(v) = cfg.get_usize("n_startup")? {
            s.n_startup_trials = v;
        }
        Ok(s)
    }

    fn space_key(space: &SearchSpace) -> String {
        let mut key = String::new();
        for (name, dist) in space {
            key.push_str(name);
            key.push('|');
            key.push_str(&dist.to_json().to_string());
            key.push(';');
        }
        key
    }

    /// Normalize internal value to [0,1] within the distribution range.
    fn normalize(dist: &Distribution, v: f64) -> f64 {
        let (lo, hi) = dist.internal_range();
        if hi <= lo {
            return 0.5;
        }
        ((v - lo) / (hi - lo)).clamp(0.0, 1.0)
    }

    fn denormalize(dist: &Distribution, u: f64) -> f64 {
        let (lo, hi) = dist.internal_range();
        lo + u.clamp(0.0, 1.0) * (hi - lo)
    }

    /// Numeric-only subset of the intersection space (CMA-ES cannot model
    /// unordered categoricals).
    fn numeric_space(ctx: &StudyContext<'_>) -> SearchSpace {
        let mut space = intersection_search_space_ctx(ctx);
        space.retain(|_, d| !matches!(d, Distribution::Categorical { .. }));
        space
    }
}

impl Sampler for CmaEsSampler {
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace {
        let space = Self::numeric_space(ctx);
        if space.is_empty()
            || ctx.complete().count() < self.n_startup_trials
        {
            return SearchSpace::new();
        }
        space
    }

    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        if space.is_empty() {
            return BTreeMap::new();
        }
        let key = Self::space_key(space);
        let dim = space.len();
        let mut guard = self.state.lock().unwrap();
        // (re)initialize when the space changes
        let reinit = match guard.as_ref() {
            Some(st) => st.space_key != key,
            None => true,
        };
        if reinit {
            // start from the best completed trial's coords (exploitation)
            let sign = ctx.direction.min_sign();
            let mut best: Option<(f64, Vec<f64>)> = None;
            for t in ctx.trials.iter().filter(|t| t.state == TrialState::Complete) {
                if let (Some(v), Some(coords)) = (t.value, trial_coords(t, space)) {
                    let loss = sign * v;
                    let norm: Vec<f64> = coords
                        .iter()
                        .zip(space.values())
                        .map(|(c, d)| Self::normalize(d, *c))
                        .collect();
                    if best.as_ref().map(|(b, _)| loss < *b).unwrap_or(true) {
                        best = Some((loss, norm));
                    }
                }
            }
            let mean = best.map(|(_, m)| m).unwrap_or_else(|| vec![0.5; dim]);
            let mut st = CmaState::new(dim, mean, self.sigma0);
            st.space_key = key.clone();
            *guard = Some(st);
        }
        let st = guard.as_mut().unwrap();

        // Tell: absorb completed trials that match outstanding asks.
        let sign = ctx.direction.min_sign();
        for t in ctx.trials.iter().filter(|t| t.state == TrialState::Complete) {
            if (t.number as i64) <= st.consumed_through {
                continue;
            }
            if let (Some(v), Some(y)) = (t.value, st.asked.remove(&t.number)) {
                st.told.push((sign * v, y));
                st.consumed_through = st.consumed_through.max(t.number as i64);
            }
        }
        while st.told.len() >= st.lambda {
            st.update();
        }

        // Ask.
        let mut rng = self.rng.lock().unwrap();
        let x = st.ask(&mut rng, trial_number);
        drop(rng);
        space
            .iter()
            .zip(x)
            .map(|((name, dist), u)| (name.clone(), Self::denormalize(dist, u)))
            .collect()
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        self.fallback.sample_independent(ctx, trial_number, name, dist)
    }

    fn name(&self) -> &'static str {
        "cmaes"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FrozenTrial, ParamValue, StudyDirection};
    use crate::sampler::testutil::completed_trial;

    fn sphere_trial(number: u64, x: f64, y: f64) -> FrozenTrial {
        let d = Distribution::float(-5.0, 5.0);
        completed_trial(
            number,
            &[
                ("x", d.clone(), ParamValue::Float(x)),
                ("y", d.clone(), ParamValue::Float(y)),
            ],
            x * x + y * y,
        )
    }

    #[test]
    fn relative_space_needs_history() {
        let s = CmaEsSampler::new(0);
        let trials: Vec<FrozenTrial> = vec![sphere_trial(0, 1.0, 1.0)];
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        assert!(s.infer_relative_search_space(&ctx).is_empty());
    }

    #[test]
    fn optimizes_sphere_end_to_end() {
        // Simulate the study loop: ask via sample_relative, evaluate
        // sphere, append to history. CMA-ES must converge toward 0.
        let s = CmaEsSampler::new(1);
        let _d = Distribution::float(-5.0, 5.0);
        let mut trials: Vec<FrozenTrial> = Vec::new();
        let mut rng = crate::util::rng::Pcg64::new(2);
        // seed random history
        for i in 0..6 {
            let x = rng.uniform_range(-5.0, 5.0);
            let y = rng.uniform_range(-5.0, 5.0);
            trials.push(sphere_trial(i, x, y));
        }
        let mut best = f64::INFINITY;
        for i in 6..160 {
            let (xv, yv);
            {
                let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
                let space = s.infer_relative_search_space(&ctx);
                assert_eq!(space.len(), 2, "space at iter {i}");
                let rel = s.sample_relative(&ctx, i, &space);
                xv = *rel.get("x").unwrap();
                yv = *rel.get("y").unwrap();
            }
            assert!((-5.0..=5.0).contains(&xv));
            let loss = xv * xv + yv * yv;
            best = best.min(loss);
            trials.push(sphere_trial(i, xv, yv));
        }
        assert!(best < 0.3, "best={best}");
        // ... and clearly better than the random seeds
        let seed_best = trials[..6]
            .iter()
            .map(|t| t.value.unwrap())
            .fold(f64::INFINITY, f64::min);
        assert!(best < seed_best);
    }

    #[test]
    fn categorical_excluded_from_space() {
        let dnum = Distribution::float(0.0, 1.0);
        let dcat = Distribution::categorical(vec!["a", "b"]);
        let trials: Vec<FrozenTrial> = (0..8)
            .map(|i| {
                completed_trial(
                    i,
                    &[
                        ("x", dnum.clone(), ParamValue::Float(0.5)),
                        ("c", dcat.clone(), ParamValue::Cat("a".into())),
                    ],
                    1.0,
                )
            })
            .collect();
        let s = CmaEsSampler::new(3);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        let space = s.infer_relative_search_space(&ctx);
        assert!(!space.contains_key("c"));
    }

    #[test]
    fn state_reinitializes_on_space_change() {
        let s = CmaEsSampler::new(4);
        let d = Distribution::float(-5.0, 5.0);
        let trials: Vec<FrozenTrial> = (0..8).map(|i| sphere_trial(i, 1.0, 1.0)).collect();
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        let space = s.infer_relative_search_space(&ctx);
        let _ = s.sample_relative(&ctx, 8, &space);
        // now a different space (x only)
        let mut space2 = SearchSpace::new();
        space2.insert("x".into(), d.clone());
        let rel = s.sample_relative(&ctx, 9, &space2);
        assert_eq!(rel.len(), 1);
        assert!(rel.contains_key("x"));
    }

    #[test]
    fn cma_state_update_shrinks_toward_optimum() {
        // Directly exercise the generation update: feed points whose best
        // cluster sits at 0.2 — the mean must move toward it.
        let mut st = CmaState::new(2, vec![0.8, 0.8], 0.3);
        let mut rng = crate::util::rng::Pcg64::new(5);
        for gen in 0..10 {
            let nums: Vec<u64> = (0..st.lambda as u64).map(|i| gen * 100 + i).collect();
            let xs: Vec<(u64, Vec<f64>)> = nums
                .iter()
                .map(|&n| (n, st.ask(&mut rng, n)))
                .collect();
            for (n, x) in xs {
                let loss = (x[0] - 0.2).powi(2) + (x[1] - 0.2).powi(2);
                let y = st.asked.remove(&n).unwrap();
                st.told.push((loss, y));
            }
            st.update();
        }
        assert!((st.mean[0] - 0.2).abs() < 0.15, "mean={:?}", st.mean);
        assert!((st.mean[1] - 0.2).abs() < 0.15, "mean={:?}", st.mean);
    }
}
