//! Tree-structured Parzen Estimator sampler (Bergstra et al. 2011) — the
//! paper's default searching strategy and its Hyperopt baseline.
//!
//! For each parameter, completed trials are split by objective into a
//! "below" (best γ-quantile) and "above" set; a Parzen estimator is fitted
//! to each; candidates are drawn from the below-model and ranked by the
//! acquisition log l(x) − log g(x).
//!
//! The candidate-scoring hot loop has two interchangeable backends:
//! * [`TpeBackend::Native`] — the in-process scorer (`ParzenEstimator::logpdf`);
//! * [`TpeBackend::External`] — any [`CandidateScorer`], in practice the
//!   AOT-compiled Pallas kernel executed through PJRT
//!   (`runtime::TpeKernelScorer`), demonstrating the L3→L1 path on the
//!   framework's own hot loop.
//! Both backends implement the same formulas (ref.py is the ground truth);
//! the perf_micro bench measures the crossover.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::core::{Distribution, TrialState};
use crate::sampler::parzen::ParzenEstimator;
use crate::sampler::random::RandomSampler;
use crate::sampler::{Sampler, SearchSpace, StudyContext};
use crate::util::rng::Pcg64;

/// Scores TPE candidates against a below/above mixture pair. `low/high`
/// are the internal-space interval; returns log l − log g per candidate.
pub trait CandidateScorer: Send + Sync {
    fn score(
        &self,
        cand: &[f64],
        below: &ParzenEstimator,
        above: &ParzenEstimator,
    ) -> Vec<f64>;

    /// Max mixture components the backend supports (kernel padding size).
    fn max_components(&self) -> usize;

    /// Max candidates per call.
    fn max_candidates(&self) -> usize;
}

/// Scoring backend selector.
pub enum TpeBackend {
    /// Pure-Rust scoring.
    Native,
    /// External scorer (PJRT-compiled Pallas kernel).
    External(Arc<dyn CandidateScorer>),
}

/// TPE configuration (defaults mirror Optuna v0.x).
pub struct TpeConfig {
    /// Random sampling for the first N trials.
    pub n_startup_trials: usize,
    /// Candidates drawn per suggest call.
    pub n_ei_candidates: usize,
    /// Cap on mixture components (minus prior); observations beyond the
    /// cap are rank-subsampled so native and kernel backends stay
    /// equivalent.
    pub max_observations: usize,
}

impl Default for TpeConfig {
    fn default() -> Self {
        TpeConfig {
            n_startup_trials: 10,
            n_ei_candidates: 24,
            max_observations: 63,
        }
    }
}

/// The sampler.
pub struct TpeSampler {
    rng: Mutex<Pcg64>,
    config: TpeConfig,
    backend: TpeBackend,
}

impl TpeSampler {
    pub fn new(seed: u64) -> Self {
        Self::with_config(seed, TpeConfig::default(), TpeBackend::Native)
    }

    pub fn with_backend(seed: u64, backend: TpeBackend) -> Self {
        Self::with_config(seed, TpeConfig::default(), backend)
    }

    pub fn with_config(seed: u64, config: TpeConfig, backend: TpeBackend) -> Self {
        TpeSampler { rng: Mutex::new(Pcg64::new(seed)), config, backend }
    }

    /// γ(n): number of trials in the "below" (good) split.
    fn gamma(n: usize) -> usize {
        ((0.25 * (n as f64).sqrt()).ceil() as usize).clamp(1, 25).min(n)
    }

    /// Observations of `name` among finished trials, with min-sign losses.
    /// Pruned trials participate with their last recorded value (mirrors
    /// Optuna: the pruning experiments rely on TPE learning from the
    /// hundreds of early-stopped trials, not just the few completed ones).
    fn observations(
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
    ) -> Vec<(f64, f64)> {
        let sign = ctx.direction.min_sign();
        ctx.trials
            .iter()
            .filter(|t| matches!(t.state, TrialState::Complete | TrialState::Pruned))
            .filter_map(|t| {
                let (d, v) = t.params.get(name)?;
                if d != dist {
                    return None;
                }
                Some((*v, sign * t.value_or_last_intermediate()?))
            })
            .collect()
    }

    /// Split observations into (below values, above values) by loss.
    fn split(mut obs: Vec<(f64, f64)>, max_each: usize) -> (Vec<f64>, Vec<f64>) {
        obs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let n_below = Self::gamma(obs.len());
        let below: Vec<f64> = obs[..n_below].iter().map(|(v, _)| *v).collect();
        let above: Vec<f64> = obs[n_below..].iter().map(|(v, _)| *v).collect();
        (subsample(below, max_each), subsample(above, max_each))
    }

    fn score(
        &self,
        cand: &[f64],
        below: &ParzenEstimator,
        above: &ParzenEstimator,
    ) -> Vec<f64> {
        match &self.backend {
            TpeBackend::Native => cand
                .iter()
                .map(|&x| below.logpdf(x) - above.logpdf(x))
                .collect(),
            TpeBackend::External(scorer) => scorer.score(cand, below, above),
        }
    }

    /// Continuous/int suggestion in internal space.
    fn suggest_numeric(
        &self,
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        let obs = Self::observations(ctx, name, dist);
        let mut rng = self.rng.lock().unwrap();
        if obs.len() < self.config.n_startup_trials {
            return RandomSampler::draw(&mut rng, dist);
        }
        let max_obs = match &self.backend {
            TpeBackend::External(s) => self.config.max_observations.min(s.max_components() - 1),
            TpeBackend::Native => self.config.max_observations,
        };
        let (below_obs, above_obs) = Self::split(obs, max_obs);
        let (lo, hi) = dist.internal_range();
        let below = ParzenEstimator::fit(&below_obs, lo, hi);
        let above = ParzenEstimator::fit(&above_obs, lo, hi);
        let n_cand = match &self.backend {
            TpeBackend::External(s) => self.config.n_ei_candidates.min(s.max_candidates()),
            TpeBackend::Native => self.config.n_ei_candidates,
        };
        let cand: Vec<f64> = (0..n_cand).map(|_| below.sample(&mut rng)).collect();
        drop(rng);
        let scores = self.score(&cand, &below, &above);
        let mut best = 0usize;
        for i in 1..cand.len() {
            if scores[i] > scores[best] {
                best = i;
            }
        }
        cand[best]
    }

    /// Categorical suggestion: weighted-count ratio over categories.
    fn suggest_categorical(
        &self,
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
        n_categories: usize,
    ) -> f64 {
        let obs = Self::observations(ctx, name, dist);
        let mut rng = self.rng.lock().unwrap();
        if obs.len() < self.config.n_startup_trials {
            return RandomSampler::draw(&mut rng, dist);
        }
        drop(rng);
        let (below, above) = Self::split(obs, usize::MAX);
        let weight = |vals: &[f64]| -> Vec<f64> {
            // Laplace-smoothed category frequencies
            let mut w = vec![1.0f64; n_categories];
            for &v in vals {
                let idx = (v.round() as i64).clamp(0, n_categories as i64 - 1) as usize;
                w[idx] += 1.0;
            }
            let total: f64 = w.iter().sum();
            w.iter().map(|x| x / total).collect()
        };
        let wb = weight(&below);
        let wa = weight(&above);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for c in 0..n_categories {
            let s = wb[c].ln() - wa[c].ln();
            if s > best_score {
                best_score = s;
                best = c;
            }
        }
        best as f64
    }
}

/// Deterministic rank-stratified subsample to at most `max` items.
fn subsample(vals: Vec<f64>, max: usize) -> Vec<f64> {
    let n = vals.len();
    if n <= max {
        return vals;
    }
    (0..max)
        .map(|i| vals[i * n / max])
        .collect()
}

impl Sampler for TpeSampler {
    fn infer_relative_search_space(&self, _ctx: &StudyContext<'_>) -> SearchSpace {
        SearchSpace::new() // TPE is a purely independent sampler
    }

    fn sample_relative(
        &self,
        _ctx: &StudyContext<'_>,
        _trial_number: u64,
        _space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        BTreeMap::new()
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        _trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        match dist {
            Distribution::Categorical { choices } => {
                self.suggest_categorical(ctx, name, dist, choices.len())
            }
            _ => self.suggest_numeric(ctx, name, dist),
        }
    }

    fn name(&self) -> &'static str {
        match self.backend {
            TpeBackend::Native => "tpe",
            TpeBackend::External(_) => "tpe-pjrt",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FrozenTrial, ParamValue, StudyDirection};
    use crate::sampler::testutil::{bowl_history, completed_trial};

    fn ctx<'a>(trials: &'a [FrozenTrial]) -> StudyContext<'a> {
        StudyContext { direction: StudyDirection::Minimize, trials }
    }

    #[test]
    fn gamma_schedule() {
        assert_eq!(TpeSampler::gamma(1), 1);
        assert_eq!(TpeSampler::gamma(16), 1);
        assert_eq!(TpeSampler::gamma(64), 2);
        assert_eq!(TpeSampler::gamma(100), 3);
        assert_eq!(TpeSampler::gamma(100_000), 25); // capped
    }

    #[test]
    fn startup_phase_is_random_but_bounded() {
        let s = TpeSampler::new(0);
        let d = Distribution::float(-1.0, 1.0);
        let trials = bowl_history(3, 7); // < n_startup
        for i in 0..50 {
            let v = s.sample_independent(&ctx(&trials), i, "x", &d);
            assert!((-1.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn concentrates_near_optimum_on_bowl() {
        // With 60 observed trials of loss = x², TPE should suggest near 0
        // far more often than uniform.
        let trials = bowl_history(60, 3);
        let s = TpeSampler::new(1);
        let d = Distribution::float(-5.0, 5.0);
        let mut near = 0;
        let n = 100;
        for i in 0..n {
            let v = s.sample_independent(&ctx(&trials), i, "x", &d);
            if v.abs() < 1.5 {
                near += 1;
            }
        }
        // uniform would give ~30%; require clear concentration
        assert!(near > 60, "near={near}/{n}");
    }

    #[test]
    fn maximize_direction_flips_split() {
        // loss = -(x²) maximized at ±5; TPE maximizing −x² must AVOID 0.
        let mut trials = Vec::new();
        let d = Distribution::float(-5.0, 5.0);
        let mut rng = Pcg64::new(5);
        for i in 0..60 {
            let x = rng.uniform_range(-5.0, 5.0);
            trials.push(completed_trial(
                i,
                &[("x", d.clone(), ParamValue::Float(x))],
                x * x, // value; with Maximize, best are large |x|
            ));
        }
        let s = TpeSampler::new(2);
        let ctx = StudyContext { direction: StudyDirection::Maximize, trials: &trials };
        let mut far = 0;
        for i in 0..100 {
            let v = s.sample_independent(&ctx, i, "x", &d);
            if v.abs() > 3.0 {
                far += 1;
            }
        }
        assert!(far > 55, "far={far}");
    }

    #[test]
    fn categorical_prefers_good_branch() {
        let d = Distribution::categorical(vec!["good", "bad"]);
        let mut trials = Vec::new();
        for i in 0..40 {
            let (cat, loss) = if i % 2 == 0 { ("good", 0.1) } else { ("bad", 1.0) };
            trials.push(completed_trial(
                i,
                &[("c", d.clone(), ParamValue::Cat(cat.into()))],
                loss + (i as f64) * 1e-4,
            ));
        }
        let s = TpeSampler::new(3);
        let v = s.sample_independent(&ctx(&trials), 40, "c", &d);
        assert_eq!(v, 0.0, "should pick 'good'");
    }

    #[test]
    fn subsample_preserves_order_and_caps() {
        let vals: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let out = subsample(vals.clone(), 10);
        assert_eq!(out.len(), 10);
        for w in out.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(subsample(vals.clone(), 200), vals);
    }

    #[test]
    fn mixed_distribution_history_filtered() {
        // Same name, different distribution must be ignored, not crash.
        let d1 = Distribution::float(0.0, 1.0);
        let d2 = Distribution::float(0.0, 2.0);
        let mut trials = bowl_history(20, 11);
        trials.push(completed_trial(
            20,
            &[("x", d2, ParamValue::Float(1.7))],
            0.01,
        ));
        let s = TpeSampler::new(4);
        let v = s.sample_independent(&ctx(&trials), 21, "x", &d1);
        assert!((0.0..=1.0).contains(&v) || (-5.0..=5.0).contains(&v));
    }

    use crate::util::rng::Pcg64;
}
