//! Tree-structured Parzen Estimator sampler (Bergstra et al. 2011) — the
//! paper's default searching strategy and its Hyperopt baseline.
//!
//! For each parameter, completed trials are split by objective into a
//! "below" (best γ-quantile) and "above" set; a Parzen estimator is fitted
//! to each; candidates are drawn from the below-model and ranked by the
//! acquisition log l(x) − log g(x).
//!
//! Two aspects of the hot path are swappable:
//!
//! * **Observation source.** When the study maintains an
//!   [`crate::core::ObservationIndex`] (the default), each suggest reads a
//!   pre-sorted loss column — the below/above split is a slice window and
//!   the per-call cost is O(γ + max_observations), independent of trial
//!   count. Without an index the sampler falls back to the pre-index scan
//!   (O(n) filter + sort per call). Both paths are decision-for-decision
//!   identical under a fixed seed (rust/tests/obs_index_equiv.rs).
//! * **Scoring backend.** [`TpeBackend::Native`] runs
//!   `ParzenEstimator::logpdf` in-process; [`TpeBackend::External`] is any
//!   [`CandidateScorer`], in practice the AOT-compiled Pallas kernel
//!   executed through PJRT (`runtime::TpeKernelScorer`). Both implement
//!   the same formulas (ref.py is the ground truth); the perf_micro bench
//!   measures the crossover.
//!
//! With [`TpeConfig::group`] set, parameters in the intersection search
//! space are additionally sampled *relatively* (before the objective
//! runs) and scored through one batched
//! [`CandidateScorer::score_groups`] call per ask — one kernel dispatch
//! per trial instead of one per parameter.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::core::{Distribution, TrialState};
use crate::sampler::kernels::{self, KernelScratch, MixtureKernel};
use crate::sampler::parzen::ParzenEstimator;
use crate::sampler::random::RandomSampler;
use crate::sampler::search_space::intersection_search_space_ctx;
use crate::sampler::{Sampler, SearchSpace, StudyContext};
use crate::util::rng::Pcg64;
use crate::util::stats::nan_max_cmp;

/// One (candidates, below, above) scoring task for
/// [`CandidateScorer::score_groups`].
pub struct ScoreGroup<'a> {
    pub cand: &'a [f64],
    pub below: &'a ParzenEstimator,
    pub above: &'a ParzenEstimator,
}

/// Scores TPE candidates against a below/above mixture pair. `low/high`
/// are the internal-space interval; returns log l − log g per candidate.
pub trait CandidateScorer: Send + Sync {
    fn score(
        &self,
        cand: &[f64],
        below: &ParzenEstimator,
        above: &ParzenEstimator,
    ) -> Vec<f64>;

    /// Score several independent groups in one call — the flattened
    /// batched layout group-mode TPE emits (one call per ask instead of
    /// one per parameter). The default delegates to [`Self::score`] per
    /// group; kernel backends can override it to amortize dispatch.
    fn score_groups(&self, groups: &[ScoreGroup<'_>]) -> Vec<Vec<f64>> {
        groups
            .iter()
            .map(|g| self.score(g.cand, g.below, g.above))
            .collect()
    }

    /// Max mixture components the backend supports (kernel padding size).
    fn max_components(&self) -> usize;

    /// Max candidates per call.
    fn max_candidates(&self) -> usize;
}

/// Scoring backend selector.
pub enum TpeBackend {
    /// Pure-Rust scoring.
    Native,
    /// External scorer (PJRT-compiled Pallas kernel).
    External(Arc<dyn CandidateScorer>),
}

/// Native scoring strategy (`tpe:kernel=scalar|vector` registry knob).
/// Both produce bit-identical suggestions — the scalar loop is kept as
/// the differential oracle for the batched kernel
/// (`rust/tests/kernel_equiv.rs`); `vector` is the default because it
/// hoists the candidate-invariant `erf`/`ln` work out of the candidate
/// loop (see [`crate::sampler::kernels::tpe_score`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TpeKernel {
    /// Per-candidate `ParzenEstimator::logpdf` calls — the oracle.
    Scalar,
    /// Batched [`crate::sampler::kernels::score_into`] over the grid.
    Vector,
}

/// TPE configuration (defaults mirror Optuna v0.x).
pub struct TpeConfig {
    /// Random sampling for the first N trials.
    pub n_startup_trials: usize,
    /// Candidates drawn per suggest call.
    pub n_ei_candidates: usize,
    /// Cap on mixture components (minus prior); observations beyond the
    /// cap are rank-subsampled so native and kernel backends stay
    /// equivalent.
    pub max_observations: usize,
    /// Opt-in batched relative sampling: parameters in the intersection
    /// search space are sampled jointly before the objective runs, with
    /// one [`CandidateScorer::score_groups`] call per ask. Off by
    /// default — the streamed per-`suggest` path stays decision-identical
    /// with prior versions.
    pub group: bool,
    /// γ quantile factor: the "below" (good) split holds
    /// `ceil(gamma_factor · √n)` observations (clamped to [1, 25]).
    pub gamma_factor: f64,
    /// Constraint-aware splitting: trials with a violated
    /// [`crate::core::FrozenTrial`] constraint are assigned an infinite
    /// loss, pinning them to the "above" (bad) model so the good-side
    /// Parzen estimator is fitted to feasible observations only. Forces
    /// the scan observation path (the index columns are constraint-blind).
    pub constraints: bool,
    /// Native scoring strategy; irrelevant for [`TpeBackend::External`].
    pub kernel: TpeKernel,
}

impl Default for TpeConfig {
    fn default() -> Self {
        TpeConfig {
            n_startup_trials: 10,
            n_ei_candidates: 24,
            max_observations: 63,
            group: false,
            gamma_factor: 0.25,
            constraints: false,
            kernel: TpeKernel::Vector,
        }
    }
}

/// Reusable suggest-call buffers: once warm, the indexed hot path
/// allocates nothing per call.
#[derive(Default)]
struct TpeScratch {
    below_obs: Vec<f64>,
    above_obs: Vec<f64>,
    cand: Vec<f64>,
    scores: Vec<f64>,
    below: ParzenEstimator,
    above: ParzenEstimator,
    // compiled mixtures + chunk buffers for the vector kernel
    below_k: MixtureKernel,
    above_k: MixtureKernel,
    kscratch: KernelScratch,
}

/// Outcome of preparing one numeric parameter for (possibly batched)
/// scoring.
enum Prepared {
    /// Resolved without scoring (startup-phase random draw).
    Drawn(f64),
    /// Fitted mixtures + candidates awaiting a score call.
    Pending {
        below: ParzenEstimator,
        above: ParzenEstimator,
        cand: Vec<f64>,
    },
}

/// The sampler.
pub struct TpeSampler {
    rng: Mutex<Pcg64>,
    config: TpeConfig,
    backend: TpeBackend,
    scratch: Mutex<TpeScratch>,
}

impl TpeSampler {
    pub fn new(seed: u64) -> Self {
        Self::with_config(seed, TpeConfig::default(), TpeBackend::Native)
    }

    pub fn with_backend(seed: u64, backend: TpeBackend) -> Self {
        Self::with_config(seed, TpeConfig::default(), backend)
    }

    pub fn with_config(seed: u64, config: TpeConfig, backend: TpeBackend) -> Self {
        TpeSampler {
            rng: Mutex::new(Pcg64::new(seed)),
            config,
            backend,
            scratch: Mutex::new(TpeScratch::default()),
        }
    }

    /// Registry constructor (spec `tpe:group=true,n_startup=20,...`).
    /// Knobs: `n_startup`, `candidates`, `max_obs`, `group`, `gamma`
    /// (quantile factor), `constraints`, `kernel` (`scalar|vector`).
    pub fn from_config(
        cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        let mut c = TpeConfig::default();
        if let Some(v) = cfg.get_usize("n_startup")? {
            c.n_startup_trials = v;
        }
        if let Some(v) = cfg.get_usize("candidates")? {
            if v == 0 {
                return Err("candidates must be >= 1".into());
            }
            c.n_ei_candidates = v;
        }
        if let Some(v) = cfg.get_usize("max_obs")? {
            if v == 0 {
                return Err("max_obs must be >= 1".into());
            }
            c.max_observations = v;
        }
        if let Some(v) = cfg.get_bool("group")? {
            c.group = v;
        }
        if let Some(v) = cfg.get_f64("gamma")? {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("gamma must be a positive finite factor, got {v}"));
            }
            c.gamma_factor = v;
        }
        if let Some(v) = cfg.get_bool("constraints")? {
            c.constraints = v;
        }
        if let Some(v) = cfg.get_str("kernel") {
            c.kernel = match v.as_str() {
                "scalar" => TpeKernel::Scalar,
                "vector" => TpeKernel::Vector,
                other => {
                    return Err(format!(
                        "kernel must be 'scalar' or 'vector', got '{other}'"
                    ))
                }
            };
        }
        Ok(Self::with_config(seed, c, TpeBackend::Native))
    }

    /// γ(n): number of trials in the "below" (good) split, under the
    /// default [`TpeConfig::gamma_factor`].
    fn gamma(n: usize) -> usize {
        Self::gamma_with(0.25, n)
    }

    /// γ(n) under an explicit quantile factor.
    fn gamma_with(factor: f64, n: usize) -> usize {
        ((factor * (n as f64).sqrt()).ceil() as usize).clamp(1, 25).min(n)
    }

    /// γ(n) under this sampler's configured factor.
    fn gamma_n(&self, n: usize) -> usize {
        Self::gamma_with(self.config.gamma_factor, n)
    }

    /// Observations of `name` among finished trials, with min-sign losses.
    /// Pruned trials participate with their last recorded value (mirrors
    /// Optuna: the pruning experiments rely on TPE learning from the
    /// hundreds of early-stopped trials, not just the few completed ones).
    ///
    /// This is the index-free fallback; with an observation index the
    /// equivalent data comes pre-sorted from
    /// [`crate::core::IndexSnapshot::param_column`].
    fn observations(
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
    ) -> Vec<(f64, f64)> {
        Self::observations_with(ctx, name, dist, false)
    }

    /// [`Self::observations`], optionally constraint-aware: with
    /// `constraints` set, an infeasible trial's loss becomes +∞, sorting
    /// it past every finite feasible loss (and thus out of the "below"
    /// split whenever enough feasible observations exist).
    fn observations_with(
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
        constraints: bool,
    ) -> Vec<(f64, f64)> {
        let sign = ctx.direction.min_sign();
        ctx.trials
            .iter()
            .filter(|t| matches!(t.state, TrialState::Complete | TrialState::Pruned))
            .filter_map(|t| {
                let (d, v) = t.params.get(name)?;
                if d != dist {
                    return None;
                }
                let mut loss = sign * t.value_or_last_intermediate()?;
                if constraints && !t.is_feasible() {
                    loss = f64::INFINITY;
                }
                Some((*v, loss))
            })
            .collect()
    }

    /// Sort (value, loss) observations by ascending loss (stable; NaN
    /// losses to the "above" end) and strip to values.
    fn sort_by_loss(mut obs: Vec<(f64, f64)>) -> Vec<f64> {
        obs.sort_by(|a, b| nan_max_cmp(&a.1, &b.1));
        obs.into_iter().map(|(v, _)| v).collect()
    }

    /// Split observations into (below values, above values) by loss —
    /// kept for the scan fallback and tests; the indexed path slices the
    /// pre-sorted column directly.
    fn split(obs: Vec<(f64, f64)>, max_each: usize) -> (Vec<f64>, Vec<f64>) {
        let sorted = Self::sort_by_loss(obs);
        let n_below = Self::gamma(sorted.len());
        (
            subsample(sorted[..n_below].to_vec(), max_each),
            subsample(sorted[n_below..].to_vec(), max_each),
        )
    }

    /// Loss-ordered observation values for `(name, dist)`: from the index
    /// when available (O(1)), otherwise scanned out of the trial snapshot
    /// (O(n log n)). `owned` is the backing store for the scan path.
    /// Constraint-aware mode always scans — the index columns order by
    /// raw loss and know nothing about feasibility.
    fn values_by_loss<'a>(
        &self,
        ctx: &'a StudyContext<'_>,
        name: &str,
        dist: &Distribution,
        owned: &'a mut Vec<f64>,
    ) -> &'a [f64] {
        match ctx.index {
            Some(ix) if !self.config.constraints => ix
                .param_column(name, dist)
                .map_or(&[][..], |c| c.values_by_loss()),
            _ => {
                *owned = Self::sort_by_loss(Self::observations_with(
                    ctx,
                    name,
                    dist,
                    self.config.constraints,
                ));
                &owned[..]
            }
        }
    }

    /// (max observations per split, candidates per call) under the
    /// backend's capacity limits.
    fn backend_limits(&self) -> (usize, usize) {
        match &self.backend {
            TpeBackend::External(s) => (
                self.config.max_observations.min(s.max_components() - 1),
                self.config.n_ei_candidates.min(s.max_candidates()),
            ),
            TpeBackend::Native => {
                (self.config.max_observations, self.config.n_ei_candidates)
            }
        }
    }

    /// Continuous/int suggestion in internal space. Runs entirely out of
    /// the reusable scratch buffers — no per-call Vec churn.
    fn suggest_numeric(
        &self,
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        let mut owned = Vec::new();
        let values = self.values_by_loss(ctx, name, dist, &mut owned);
        if values.len() < self.config.n_startup_trials {
            let mut rng = self.rng.lock().unwrap();
            return RandomSampler::draw(&mut rng, dist);
        }
        let (max_obs, n_cand) = self.backend_limits();
        let n_below = self.gamma_n(values.len());
        let (lo, hi) = dist.internal_range();

        let mut scratch = self.scratch.lock().unwrap();
        {
            let s = &mut *scratch;
            subsample_into(&values[..n_below], max_obs, &mut s.below_obs);
            subsample_into(&values[n_below..], max_obs, &mut s.above_obs);
            s.below.fit_into(&s.below_obs, lo, hi);
            s.above.fit_into(&s.above_obs, lo, hi);
            s.cand.clear();
            let mut rng = self.rng.lock().unwrap();
            for _ in 0..n_cand {
                s.cand.push(s.below.sample(&mut rng));
            }
        }
        match &self.backend {
            TpeBackend::Native => {
                // cheap in-process scoring: stay inside the scratch lock,
                // zero allocation per call
                let s = &mut *scratch;
                match self.config.kernel {
                    TpeKernel::Vector => {
                        s.below_k.compile_from(&s.below);
                        s.above_k.compile_from(&s.above);
                        kernels::score_into(
                            &s.cand,
                            &s.below_k,
                            &s.above_k,
                            &mut s.kscratch,
                            &mut s.scores,
                        );
                    }
                    TpeKernel::Scalar => {
                        s.scores.clear();
                        for &x in &s.cand {
                            s.scores.push(s.below.logpdf(x) - s.above.logpdf(x));
                        }
                    }
                }
                let mut best = 0usize;
                for i in 1..s.cand.len() {
                    if s.scores[i] > s.scores[best] {
                        best = i;
                    }
                }
                s.cand[best]
            }
            TpeBackend::External(scorer) => {
                // kernel dispatch dominates and must overlap across
                // workers: move the inputs out and release the lock first
                let cand = std::mem::take(&mut scratch.cand);
                let below = scratch.below.clone();
                let above = scratch.above.clone();
                drop(scratch);
                let scores = scorer.score(&cand, &below, &above);
                let mut best = 0usize;
                for i in 1..cand.len() {
                    if scores[i] > scores[best] {
                        best = i;
                    }
                }
                cand[best]
            }
        }
    }

    /// Like [`Self::suggest_numeric`] but defers scoring, so group-mode
    /// relative sampling can batch every parameter's candidates into one
    /// [`CandidateScorer::score_groups`] call.
    fn prepare_numeric(
        &self,
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
    ) -> Prepared {
        let mut owned = Vec::new();
        let values = self.values_by_loss(ctx, name, dist, &mut owned);
        if values.len() < self.config.n_startup_trials {
            let mut rng = self.rng.lock().unwrap();
            return Prepared::Drawn(RandomSampler::draw(&mut rng, dist));
        }
        let (max_obs, n_cand) = self.backend_limits();
        let n_below = self.gamma_n(values.len());
        let (lo, hi) = dist.internal_range();
        let below =
            ParzenEstimator::fit(&subsample(values[..n_below].to_vec(), max_obs), lo, hi);
        let above =
            ParzenEstimator::fit(&subsample(values[n_below..].to_vec(), max_obs), lo, hi);
        let mut cand = Vec::with_capacity(n_cand);
        {
            let mut rng = self.rng.lock().unwrap();
            for _ in 0..n_cand {
                cand.push(below.sample(&mut rng));
            }
        }
        Prepared::Pending { below, above, cand }
    }

    /// Categorical suggestion: weighted-count ratio over categories.
    fn suggest_categorical(
        &self,
        ctx: &StudyContext<'_>,
        name: &str,
        dist: &Distribution,
        n_categories: usize,
    ) -> f64 {
        let mut owned = Vec::new();
        let values = self.values_by_loss(ctx, name, dist, &mut owned);
        if values.len() < self.config.n_startup_trials {
            let mut rng = self.rng.lock().unwrap();
            return RandomSampler::draw(&mut rng, dist);
        }
        let (below, above) = values.split_at(self.gamma_n(values.len()));
        let weight = |vals: &[f64]| -> Vec<f64> {
            // Laplace-smoothed category frequencies
            let mut w = vec![1.0f64; n_categories];
            for &v in vals {
                let idx = (v.round() as i64).clamp(0, n_categories as i64 - 1) as usize;
                w[idx] += 1.0;
            }
            let total: f64 = w.iter().sum();
            w.iter().map(|x| x / total).collect()
        };
        let wb = weight(below);
        let wa = weight(above);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for c in 0..n_categories {
            let s = wb[c].ln() - wa[c].ln();
            if s > best_score {
                best_score = s;
                best = c;
            }
        }
        best as f64
    }
}

/// Deterministic rank-stratified subsample to at most `max` items.
fn subsample(vals: Vec<f64>, max: usize) -> Vec<f64> {
    let mut out = Vec::new();
    subsample_into(&vals, max, &mut out);
    out
}

/// [`subsample`] into a reusable buffer (identical picks).
fn subsample_into(vals: &[f64], max: usize, out: &mut Vec<f64>) {
    out.clear();
    let n = vals.len();
    if n <= max {
        out.extend_from_slice(vals);
        return;
    }
    out.extend((0..max).map(|i| vals[i * n / max]));
}

impl Sampler for TpeSampler {
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace {
        if !self.config.group {
            return SearchSpace::new(); // purely independent sampling
        }
        intersection_search_space_ctx(ctx)
    }

    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        _trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        if !self.config.group || space.is_empty() {
            return out;
        }
        // Prepare every numeric parameter first, then score all of them
        // through ONE batched call: External backends pay one dispatch
        // per ask instead of one per parameter.
        let mut pending: Vec<(String, ParzenEstimator, ParzenEstimator, Vec<f64>)> =
            Vec::new();
        for (name, dist) in space {
            if let Distribution::Categorical { choices } = dist {
                let v = self.suggest_categorical(ctx, name, dist, choices.len());
                out.insert(name.clone(), v);
                continue;
            }
            match self.prepare_numeric(ctx, name, dist) {
                Prepared::Drawn(v) => {
                    out.insert(name.clone(), v);
                }
                Prepared::Pending { below, above, cand } => {
                    pending.push((name.clone(), below, above, cand));
                }
            }
        }
        if pending.is_empty() {
            return out;
        }
        let scores: Vec<Vec<f64>> = match &self.backend {
            TpeBackend::Native => match self.config.kernel {
                TpeKernel::Vector => {
                    // reuse the suggest-path scratch (compiled mixtures +
                    // chunk buffers) across the batch
                    let mut scratch = self.scratch.lock().unwrap();
                    let s = &mut *scratch;
                    pending
                        .iter()
                        .map(|(_, b, a, c)| {
                            s.below_k.compile_from(b);
                            s.above_k.compile_from(a);
                            let mut out = Vec::with_capacity(c.len());
                            kernels::score_into(
                                c,
                                &s.below_k,
                                &s.above_k,
                                &mut s.kscratch,
                                &mut out,
                            );
                            out
                        })
                        .collect()
                }
                TpeKernel::Scalar => pending
                    .iter()
                    .map(|(_, b, a, c)| {
                        c.iter().map(|&x| b.logpdf(x) - a.logpdf(x)).collect()
                    })
                    .collect(),
            },
            TpeBackend::External(scorer) => {
                let groups: Vec<ScoreGroup<'_>> = pending
                    .iter()
                    .map(|(_, b, a, c)| ScoreGroup { cand: c, below: b, above: a })
                    .collect();
                scorer.score_groups(&groups)
            }
        };
        for ((name, _, _, cand), sc) in pending.iter().zip(&scores) {
            let mut best = 0usize;
            for i in 1..cand.len() {
                if sc[i] > sc[best] {
                    best = i;
                }
            }
            out.insert(name.clone(), cand[best]);
        }
        out
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        _trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        match dist {
            Distribution::Categorical { choices } => {
                self.suggest_categorical(ctx, name, dist, choices.len())
            }
            _ => self.suggest_numeric(ctx, name, dist),
        }
    }

    fn name(&self) -> &'static str {
        match self.backend {
            TpeBackend::Native => "tpe",
            TpeBackend::External(_) => "tpe-pjrt",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FrozenTrial, ObservationIndex, ParamValue, StudyDirection};
    use crate::sampler::testutil::{bowl_history, completed_trial};

    fn ctx<'a>(trials: &'a [FrozenTrial]) -> StudyContext<'a> {
        StudyContext::new(StudyDirection::Minimize, trials)
    }

    #[test]
    fn gamma_schedule() {
        assert_eq!(TpeSampler::gamma(1), 1);
        assert_eq!(TpeSampler::gamma(16), 1);
        assert_eq!(TpeSampler::gamma(64), 2);
        assert_eq!(TpeSampler::gamma(100), 3);
        assert_eq!(TpeSampler::gamma(100_000), 25); // capped
    }

    #[test]
    fn startup_phase_is_random_but_bounded() {
        let s = TpeSampler::new(0);
        let d = Distribution::float(-1.0, 1.0);
        let trials = bowl_history(3, 7); // < n_startup
        for i in 0..50 {
            let v = s.sample_independent(&ctx(&trials), i, "x", &d);
            assert!((-1.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn concentrates_near_optimum_on_bowl() {
        // With 60 observed trials of loss = x², TPE should suggest near 0
        // far more often than uniform.
        let trials = bowl_history(60, 3);
        let s = TpeSampler::new(1);
        let d = Distribution::float(-5.0, 5.0);
        let mut near = 0;
        let n = 100;
        for i in 0..n {
            let v = s.sample_independent(&ctx(&trials), i, "x", &d);
            if v.abs() < 1.5 {
                near += 1;
            }
        }
        // uniform would give ~30%; require clear concentration
        assert!(near > 60, "near={near}/{n}");
    }

    #[test]
    fn indexed_and_scan_paths_agree_suggestion_for_suggestion() {
        let trials = bowl_history(80, 13);
        let d = Distribution::float(-5.0, 5.0);
        let mut ix = ObservationIndex::new(StudyDirection::Minimize);
        let snap = ix.apply(&trials, 1);
        // two samplers with the same seed, one per observation source
        let scan = TpeSampler::new(17);
        let indexed = TpeSampler::new(17);
        for i in 0..50 {
            let a = scan.sample_independent(&ctx(&trials), i, "x", &d);
            let c = StudyContext::with_index(
                StudyDirection::Minimize,
                &trials,
                Some(&*snap),
            );
            let b = indexed.sample_independent(&c, i, "x", &d);
            assert_eq!(a, b, "suggestion {i} diverged");
        }
    }

    #[test]
    fn maximize_direction_flips_split() {
        // loss = -(x²) maximized at ±5; TPE maximizing −x² must AVOID 0.
        let mut trials = Vec::new();
        let d = Distribution::float(-5.0, 5.0);
        let mut rng = Pcg64::new(5);
        for i in 0..60 {
            let x = rng.uniform_range(-5.0, 5.0);
            trials.push(completed_trial(
                i,
                &[("x", d.clone(), ParamValue::Float(x))],
                x * x, // value; with Maximize, best are large |x|
            ));
        }
        let s = TpeSampler::new(2);
        let ctx = StudyContext::new(StudyDirection::Maximize, &trials);
        let mut far = 0;
        for i in 0..100 {
            let v = s.sample_independent(&ctx, i, "x", &d);
            if v.abs() > 3.0 {
                far += 1;
            }
        }
        assert!(far > 55, "far={far}");
    }

    #[test]
    fn categorical_prefers_good_branch() {
        let d = Distribution::categorical(vec!["good", "bad"]);
        let mut trials = Vec::new();
        for i in 0..40 {
            let (cat, loss) = if i % 2 == 0 { ("good", 0.1) } else { ("bad", 1.0) };
            trials.push(completed_trial(
                i,
                &[("c", d.clone(), ParamValue::Cat(cat.into()))],
                loss + (i as f64) * 1e-4,
            ));
        }
        let s = TpeSampler::new(3);
        let v = s.sample_independent(&ctx(&trials), 40, "c", &d);
        assert_eq!(v, 0.0, "should pick 'good'");
    }

    #[test]
    fn nan_loss_does_not_panic_and_is_ranked_worst() {
        // A diverged trial tell'd with NaN used to panic the
        // partial_cmp(..).unwrap() sort in split(); it must now be sorted
        // to the "above" end and sampling must proceed.
        let d = Distribution::float(-5.0, 5.0);
        let mut trials = bowl_history(30, 9);
        let mut diverged =
            completed_trial(30, &[("x", d.clone(), ParamValue::Float(4.9))], 0.0);
        diverged.value = Some(f64::NAN);
        trials.push(diverged);
        let s = TpeSampler::new(7);
        for i in 0..20 {
            let v = s.sample_independent(&ctx(&trials), i, "x", &d);
            assert!((-5.0..=5.0).contains(&v));
        }
        // and the NaN observation lands last in the loss ordering
        let sorted = TpeSampler::sort_by_loss(TpeSampler::observations(
            &ctx(&trials),
            "x",
            &d,
        ));
        assert_eq!(*sorted.last().unwrap(), 4.9);
    }

    #[test]
    fn group_mode_samples_intersection_relatively() {
        let trials = bowl_history(40, 21);
        let s = TpeSampler::with_config(
            4,
            TpeConfig { group: true, ..Default::default() },
            TpeBackend::Native,
        );
        let c = ctx(&trials);
        let space = s.infer_relative_search_space(&c);
        assert_eq!(space.len(), 1, "intersection is {{x}}");
        let rel = s.sample_relative(&c, 40, &space);
        let x = rel["x"];
        assert!((-5.0..=5.0).contains(&x));
        // default (non-group) config opts out of relative sampling
        let plain = TpeSampler::new(4);
        assert!(plain.infer_relative_search_space(&c).is_empty());
    }

    #[test]
    fn group_mode_batches_one_score_call_per_ask() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        /// Counts score_groups calls; scores natively.
        struct CountingScorer {
            group_calls: AtomicUsize,
            single_calls: AtomicUsize,
        }
        impl CandidateScorer for CountingScorer {
            fn score(
                &self,
                cand: &[f64],
                below: &ParzenEstimator,
                above: &ParzenEstimator,
            ) -> Vec<f64> {
                self.single_calls.fetch_add(1, Ordering::SeqCst);
                cand.iter()
                    .map(|&x| below.logpdf(x) - above.logpdf(x))
                    .collect()
            }
            fn score_groups(&self, groups: &[ScoreGroup<'_>]) -> Vec<Vec<f64>> {
                self.group_calls.fetch_add(1, Ordering::SeqCst);
                groups
                    .iter()
                    .map(|g| {
                        g.cand
                            .iter()
                            .map(|&x| g.below.logpdf(x) - g.above.logpdf(x))
                            .collect()
                    })
                    .collect()
            }
            fn max_components(&self) -> usize {
                usize::MAX
            }
            fn max_candidates(&self) -> usize {
                usize::MAX
            }
        }

        let d = Distribution::float(-5.0, 5.0);
        let mut rng = Pcg64::new(31);
        let trials: Vec<FrozenTrial> = (0..30)
            .map(|i| {
                let x = rng.uniform_range(-5.0, 5.0);
                let y = rng.uniform_range(-5.0, 5.0);
                completed_trial(
                    i,
                    &[
                        ("x", d.clone(), ParamValue::Float(x)),
                        ("y", d.clone(), ParamValue::Float(y)),
                    ],
                    x * x + y * y,
                )
            })
            .collect();
        let scorer = Arc::new(CountingScorer {
            group_calls: AtomicUsize::new(0),
            single_calls: AtomicUsize::new(0),
        });
        let s = TpeSampler::with_config(
            5,
            TpeConfig { group: true, ..Default::default() },
            TpeBackend::External(scorer.clone()),
        );
        let c = ctx(&trials);
        let space = s.infer_relative_search_space(&c);
        assert_eq!(space.len(), 2);
        let rel = s.sample_relative(&c, 30, &space);
        assert_eq!(rel.len(), 2);
        assert_eq!(
            scorer.group_calls.load(Ordering::SeqCst),
            1,
            "two numeric params, ONE batched call"
        );
        assert_eq!(scorer.single_calls.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn gamma_factor_is_configurable() {
        assert_eq!(TpeSampler::gamma_with(0.25, 100), TpeSampler::gamma(100));
        assert_eq!(TpeSampler::gamma_with(0.5, 100), 5);
        assert_eq!(TpeSampler::gamma_with(1.0, 100), 10);
        assert_eq!(TpeSampler::gamma_with(10.0, 100), 25); // capped
        let s = TpeSampler::with_config(
            0,
            TpeConfig { gamma_factor: 0.5, ..Default::default() },
            TpeBackend::Native,
        );
        assert_eq!(s.gamma_n(100), 5);
    }

    #[test]
    fn constraint_aware_split_avoids_infeasible_optimum() {
        // Trials at x<0 have the best losses but violate a constraint;
        // feasible trials live at x>0 with moderate losses. Blind TPE
        // chases the infeasible lobe; constraint-aware TPE must not.
        let d = Distribution::float(-5.0, 5.0);
        let mut rng = Pcg64::new(11);
        let mut trials = Vec::new();
        for i in 0..60 {
            let (x, loss, viol) = if i % 2 == 0 {
                let x = rng.uniform_range(-4.0, -3.0);
                (x, 0.01 * (x + 3.5).powi(2), 1.0) // great loss, infeasible
            } else {
                let x = rng.uniform_range(2.0, 4.0);
                (x, 1.0 + 0.1 * (x - 3.0).powi(2), -1.0) // ok loss, feasible
            };
            let mut t =
                completed_trial(i, &[("x", d.clone(), ParamValue::Float(x))], loss);
            t.constraints = vec![viol];
            trials.push(t);
        }
        let aware = TpeSampler::with_config(
            6,
            TpeConfig { constraints: true, ..Default::default() },
            TpeBackend::Native,
        );
        let blind = TpeSampler::new(6);
        let c = ctx(&trials);
        let (mut aware_pos, mut blind_neg) = (0, 0);
        for i in 0..50 {
            if aware.sample_independent(&c, i, "x", &d) > 0.0 {
                aware_pos += 1;
            }
            if blind.sample_independent(&c, i, "x", &d) < 0.0 {
                blind_neg += 1;
            }
        }
        assert!(aware_pos > 40, "aware sampler stuck infeasible: {aware_pos}/50");
        assert!(blind_neg > 40, "blind ablation should chase x<0: {blind_neg}/50");
    }

    #[test]
    fn from_config_parses_knobs() {
        let mut cfg =
            crate::registry::SpecConfig::parse_pairs("n_startup=3,gamma=0.5,group=yes")
                .unwrap();
        let s = TpeSampler::from_config(&mut cfg, 9).unwrap();
        cfg.finish().unwrap();
        assert_eq!(s.config.n_startup_trials, 3);
        assert!(s.config.group);
        assert_eq!(s.gamma_n(100), 5);
        let mut bad = crate::registry::SpecConfig::parse_pairs("gamma=-1").unwrap();
        let err = TpeSampler::from_config(&mut bad, 0).unwrap_err();
        assert!(err.contains("gamma"), "{err}");
    }

    #[test]
    fn vector_and_scalar_kernels_suggest_identically() {
        // the batched kernel must be a pure codegen change: every
        // suggestion bit-identical to the scalar-oracle sampler under
        // the same seed, with and without an observation index
        let d = Distribution::float(-5.0, 5.0);
        let trials = bowl_history(70, 29);
        let mut ix = ObservationIndex::new(StudyDirection::Minimize);
        let snap = ix.apply(&trials, 1);
        let mk = |kernel| {
            TpeSampler::with_config(
                77,
                TpeConfig { kernel, ..Default::default() },
                TpeBackend::Native,
            )
        };
        let (vec_s, sca_s) = (mk(TpeKernel::Vector), mk(TpeKernel::Scalar));
        for i in 0..60 {
            let c = if i % 2 == 0 {
                StudyContext::new(StudyDirection::Minimize, &trials)
            } else {
                StudyContext::with_index(StudyDirection::Minimize, &trials, Some(&*snap))
            };
            let a = vec_s.sample_independent(&c, i, "x", &d);
            let b = sca_s.sample_independent(&c, i, "x", &d);
            assert_eq!(a.to_bits(), b.to_bits(), "suggestion {i} diverged");
        }
    }

    #[test]
    fn group_mode_kernels_agree() {
        let trials = bowl_history(40, 33);
        let mk = |kernel| {
            TpeSampler::with_config(
                8,
                TpeConfig { group: true, kernel, ..Default::default() },
                TpeBackend::Native,
            )
        };
        let (vec_s, sca_s) = (mk(TpeKernel::Vector), mk(TpeKernel::Scalar));
        let c = ctx(&trials);
        let space = vec_s.infer_relative_search_space(&c);
        for i in 0..20 {
            let a = vec_s.sample_relative(&c, i, &space);
            let b = sca_s.sample_relative(&c, i, &space);
            assert_eq!(a.len(), b.len());
            for (k, v) in &a {
                assert_eq!(v.to_bits(), b[k].to_bits(), "param {k} diverged at ask {i}");
            }
        }
    }

    #[test]
    fn from_config_parses_kernel_knob() {
        let mut cfg = crate::registry::SpecConfig::parse_pairs("kernel=scalar").unwrap();
        let s = TpeSampler::from_config(&mut cfg, 0).unwrap();
        cfg.finish().unwrap();
        assert_eq!(s.config.kernel, TpeKernel::Scalar);
        assert_eq!(TpeConfig::default().kernel, TpeKernel::Vector);
        let mut bad = crate::registry::SpecConfig::parse_pairs("kernel=avx").unwrap();
        let err = TpeSampler::from_config(&mut bad, 0).unwrap_err();
        assert!(err.contains("kernel"), "{err}");
    }

    #[test]
    fn subsample_preserves_order_and_caps() {
        let vals: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let out = subsample(vals.clone(), 10);
        assert_eq!(out.len(), 10);
        for w in out.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(subsample(vals.clone(), 200), vals);
    }

    #[test]
    fn split_still_serves_scan_fallback() {
        let obs: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, (20 - i) as f64)).collect();
        let (below, above) = TpeSampler::split(obs, usize::MAX);
        assert_eq!(below.len(), TpeSampler::gamma(20));
        assert_eq!(below[0], 19.0, "lowest loss first");
        assert_eq!(below.len() + above.len(), 20);
    }

    #[test]
    fn mixed_distribution_history_filtered() {
        // Same name, different distribution must be ignored, not crash.
        let d1 = Distribution::float(0.0, 1.0);
        let d2 = Distribution::float(0.0, 2.0);
        let mut trials = bowl_history(20, 11);
        trials.push(completed_trial(
            20,
            &[("x", d2, ParamValue::Float(1.7))],
            0.01,
        ));
        let s = TpeSampler::new(4);
        let v = s.sample_independent(&ctx(&trials), 21, "x", &d1);
        assert!((0.0..=1.0).contains(&v) || (-5.0..=5.0).contains(&v));
    }

    use crate::util::rng::Pcg64;
}
