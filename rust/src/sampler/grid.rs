//! Exhaustive grid sampler (extension feature; useful for ablations and
//! deterministic tests).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::core::Distribution;
use crate::sampler::random::RandomSampler;
use crate::sampler::{Sampler, SearchSpace, StudyContext};

/// Walks the Cartesian product of per-parameter internal-value grids in
/// trial-number order, wrapping around when exhausted. Parameters outside
/// the grid fall back to random sampling.
pub struct GridSampler {
    space: SearchSpace,
    /// parallel to `space` (BTreeMap order): grid points per parameter
    grids: Vec<Vec<f64>>,
    fallback: RandomSampler,
    counter: Mutex<u64>,
}

impl GridSampler {
    /// `axes`: (name, distribution, internal grid points).
    pub fn new(axes: Vec<(String, Distribution, Vec<f64>)>, seed: u64) -> Self {
        let mut space = SearchSpace::new();
        let mut by_name: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for (name, dist, grid) in axes {
            assert!(!grid.is_empty(), "empty grid for {name}");
            space.insert(name.clone(), dist);
            by_name.insert(name, grid);
        }
        let grids = by_name.into_values().collect();
        GridSampler {
            space,
            grids,
            fallback: RandomSampler::new(seed),
            counter: Mutex::new(0),
        }
    }

    /// Total number of grid points.
    pub fn len(&self) -> u64 {
        self.grids.iter().map(|g| g.len() as u64).product()
    }

    pub fn is_empty(&self) -> bool {
        self.grids.is_empty()
    }

    fn point(&self, index: u64) -> Vec<f64> {
        let mut rem = index % self.len();
        let mut out = Vec::with_capacity(self.grids.len());
        for g in &self.grids {
            let k = (rem % g.len() as u64) as usize;
            rem /= g.len() as u64;
            out.push(g[k]);
        }
        out
    }
}

impl Sampler for GridSampler {
    fn infer_relative_search_space(&self, _ctx: &StudyContext<'_>) -> SearchSpace {
        self.space.clone()
    }

    fn sample_relative(
        &self,
        _ctx: &StudyContext<'_>,
        _trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        // Use an internal counter (not trial_number) so several grid
        // sampler studies sharing storage don't skip points.
        let mut c = self.counter.lock().unwrap();
        let idx = *c;
        *c += 1;
        drop(c);
        let coords = self.point(idx);
        space.keys().cloned().zip(coords).collect()
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        self.fallback.sample_independent(ctx, trial_number, name, dist)
    }

    fn name(&self) -> &'static str {
        "grid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::StudyDirection;

    fn mk() -> GridSampler {
        GridSampler::new(
            vec![
                ("a".into(), Distribution::float(0.0, 1.0), vec![0.0, 0.5, 1.0]),
                ("b".into(), Distribution::int(0, 1), vec![0.0, 1.0]),
            ],
            0,
        )
    }

    #[test]
    fn covers_full_product() {
        let g = mk();
        assert_eq!(g.len(), 6);
        let ctx = StudyContext::new(StudyDirection::Minimize, &[]);
        let space = g.infer_relative_search_space(&ctx);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..6 {
            let rel = g.sample_relative(&ctx, i, &space);
            seen.insert(format!("{:.1}-{:.0}", rel["a"], rel["b"]));
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn wraps_around() {
        let g = mk();
        let ctx = StudyContext::new(StudyDirection::Minimize, &[]);
        let space = g.infer_relative_search_space(&ctx);
        let first = g.sample_relative(&ctx, 0, &space);
        for i in 1..6 {
            let _ = g.sample_relative(&ctx, i, &space);
        }
        let again = g.sample_relative(&ctx, 6, &space);
        assert_eq!(first, again);
    }
}
