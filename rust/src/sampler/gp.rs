//! Gaussian-process EI sampler — the GPyOpt adversary of Fig 9/10.
//!
//! Matérn-5/2 kernel on the normalized intersection space, marginal-
//! likelihood lengthscale selection over a small grid, and expected-
//! improvement maximized over random candidates. Cubic-in-n Cholesky
//! solves make it the slow-but-sample-efficient rival the paper measures
//! "an order-of-magnitude" slower per trial (Fig 10) — our bench
//! reproduces exactly that trade-off.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::core::{Distribution, TrialState};
use crate::sampler::random::RandomSampler;
use crate::sampler::search_space::{intersection_search_space_ctx, trial_coords};
use crate::sampler::{Sampler, SearchSpace, StudyContext};
use crate::util::linalg::{cholesky, solve_lower, solve_lower_t, Mat};
use crate::util::rng::Pcg64;
use crate::util::stats::{erf, mean, std_dev};

/// GP-EI relational sampler.
pub struct GpSampler {
    rng: Mutex<Pcg64>,
    fallback: RandomSampler,
    /// Trials before the GP takes over.
    pub n_startup_trials: usize,
    /// Most-recent-trials cap (bounds the O(n³) solve).
    pub max_observations: usize,
    /// EI candidates per suggestion.
    pub n_candidates: usize,
    /// Lengthscale grid for marginal-likelihood selection.
    pub lengthscales: Vec<f64>,
    /// Observation noise (jitter).
    pub noise: f64,
}

impl GpSampler {
    pub fn new(seed: u64) -> Self {
        GpSampler {
            rng: Mutex::new(Pcg64::new(seed)),
            fallback: RandomSampler::new(seed ^ 0x6b0a),
            n_startup_trials: 5,
            max_observations: 100,
            n_candidates: 256,
            lengthscales: vec![0.1, 0.25, 0.5, 1.0],
            noise: 1e-6,
        }
    }

    /// Registry constructor (spec `gp:n_startup=5,max_obs=100,...`).
    pub fn from_config(
        cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        let mut s = GpSampler::new(seed);
        if let Some(v) = cfg.get_usize("n_startup")? {
            s.n_startup_trials = v;
        }
        if let Some(v) = cfg.get_usize("max_obs")? {
            if v == 0 {
                return Err("max_obs must be >= 1".into());
            }
            s.max_observations = v;
        }
        if let Some(v) = cfg.get_usize("candidates")? {
            if v == 0 {
                return Err("candidates must be >= 1".into());
            }
            s.n_candidates = v;
        }
        if let Some(v) = cfg.get_f64("noise")? {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("noise must be positive and finite, got {v}"));
            }
            s.noise = v;
        }
        Ok(s)
    }

    fn matern52(r2: f64, ls: f64) -> f64 {
        let r = r2.sqrt() / ls;
        let s5r = 5.0f64.sqrt() * r;
        (1.0 + s5r + 5.0 * r * r / 3.0) * (-s5r).exp()
    }

    fn kernel_matrix(xs: &[Vec<f64>], ls: f64, noise: f64) -> Mat {
        let n = xs.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let r2: f64 = xs[i]
                    .iter()
                    .zip(&xs[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                let v = Self::matern52(r2, ls);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        for i in 0..n {
            k[(i, i)] += noise;
        }
        k
    }

    fn kernel_vec(xs: &[Vec<f64>], x: &[f64], ls: f64) -> Vec<f64> {
        xs.iter()
            .map(|xi| {
                let r2: f64 = xi.iter().zip(x).map(|(a, b)| (a - b) * (a - b)).sum();
                Self::matern52(r2, ls)
            })
            .collect()
    }

    /// log marginal likelihood (up to constants) given Cholesky L of K.
    fn log_marginal(l: &Mat, alpha: &[f64], y: &[f64]) -> f64 {
        let fit: f64 = y.iter().zip(alpha).map(|(a, b)| a * b).sum();
        let logdet: f64 = (0..l.rows).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0;
        -0.5 * fit - 0.5 * logdet
    }

    fn normal_pdf(z: f64) -> f64 {
        (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
    }

    fn normal_cdf(z: f64) -> f64 {
        0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
    }

    /// Expected improvement for minimization over standardized losses.
    fn ei(mu: f64, sigma: f64, best: f64) -> f64 {
        if sigma <= 1e-12 {
            return (best - mu).max(0.0);
        }
        let z = (best - mu) / sigma;
        (best - mu) * Self::normal_cdf(z) + sigma * Self::normal_pdf(z)
    }
}

impl Sampler for GpSampler {
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace {
        let mut space = intersection_search_space_ctx(ctx);
        space.retain(|_, d| !matches!(d, Distribution::Categorical { .. }));
        if space.is_empty() || ctx.complete().count() < self.n_startup_trials {
            return SearchSpace::new();
        }
        space
    }

    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        _trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        if space.is_empty() {
            return BTreeMap::new();
        }
        // Gather normalized observations (most recent max_observations).
        let sign = ctx.direction.min_sign();
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for t in ctx
            .trials
            .iter()
            .filter(|t| t.state == TrialState::Complete)
            .rev()
            .take(self.max_observations)
        {
            if let (Some(v), Some(coords)) = (t.value, trial_coords(t, space)) {
                let norm: Vec<f64> = coords
                    .iter()
                    .zip(space.values())
                    .map(|(c, d)| {
                        let (lo, hi) = d.internal_range();
                        if hi <= lo { 0.5 } else { ((c - lo) / (hi - lo)).clamp(0.0, 1.0) }
                    })
                    .collect();
                xs.push(norm);
                ys.push(sign * v);
            }
        }
        if xs.len() < 2 {
            return BTreeMap::new();
        }
        // Standardize losses.
        let m = mean(&ys);
        let s = std_dev(&ys).max(1e-12);
        let y_std: Vec<f64> = ys.iter().map(|y| (y - m) / s).collect();

        // Lengthscale by marginal likelihood.
        let mut best_fit: Option<(f64, f64, Mat, Vec<f64>)> = None; // (lml, ls, L, alpha)
        for &ls in &self.lengthscales {
            let k = Self::kernel_matrix(&xs, ls, self.noise.max(1e-9));
            if let Some(l) = cholesky(&k) {
                let alpha = solve_lower_t(&l, &solve_lower(&l, &y_std));
                let lml = Self::log_marginal(&l, &alpha, &y_std);
                if best_fit.as_ref().map(|(b, ..)| lml > *b).unwrap_or(true) {
                    best_fit = Some((lml, ls, l, alpha));
                }
            }
        }
        let Some((_, ls, l_chol, alpha)) = best_fit else {
            return BTreeMap::new();
        };
        let best_y = y_std.iter().cloned().fold(f64::INFINITY, f64::min);

        // EI over random candidates (+ jittered copies of the incumbent).
        let dim = space.len();
        let mut rng = self.rng.lock().unwrap();
        let incumbent = xs[y_std
            .iter()
            .enumerate()
            .min_by(|a, b| crate::util::stats::nan_max_cmp(a.1, b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)]
        .clone();
        let mut best_cand: Option<(f64, Vec<f64>)> = None;
        for c in 0..self.n_candidates {
            let cand: Vec<f64> = if c % 4 == 0 {
                // local perturbation of the incumbent
                incumbent
                    .iter()
                    .map(|v| (v + 0.05 * rng.normal()).clamp(0.0, 1.0))
                    .collect()
            } else {
                (0..dim).map(|_| rng.uniform()).collect()
            };
            let kv = Self::kernel_vec(&xs, &cand, ls);
            let mu: f64 = kv.iter().zip(&alpha).map(|(a, b)| a * b).sum();
            let v = solve_lower(&l_chol, &kv);
            let var = (1.0 - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
            let ei = Self::ei(mu, var.sqrt(), best_y);
            if best_cand.as_ref().map(|(b, _)| ei > *b).unwrap_or(true) {
                best_cand = Some((ei, cand));
            }
        }
        drop(rng);
        let chosen = best_cand.map(|(_, c)| c).unwrap_or(incumbent);
        space
            .iter()
            .zip(chosen)
            .map(|((name, dist), u)| {
                let (lo, hi) = dist.internal_range();
                (name.clone(), lo + u * (hi - lo))
            })
            .collect()
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        self.fallback.sample_independent(ctx, trial_number, name, dist)
    }

    fn name(&self) -> &'static str {
        "gp"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FrozenTrial, ParamValue, StudyDirection};
    use crate::sampler::testutil::completed_trial;

    fn quad_trial(number: u64, x: f64) -> FrozenTrial {
        let d = Distribution::float(0.0, 1.0);
        completed_trial(
            number,
            &[("x", d, ParamValue::Float(x))],
            (x - 0.3) * (x - 0.3),
        )
    }

    #[test]
    fn matern_kernel_properties() {
        assert!((GpSampler::matern52(0.0, 0.5) - 1.0).abs() < 1e-12);
        assert!(GpSampler::matern52(1.0, 0.5) < 1.0);
        assert!(GpSampler::matern52(1.0, 0.5) > GpSampler::matern52(4.0, 0.5));
    }

    #[test]
    fn ei_positive_below_best() {
        assert!(GpSampler::ei(-1.0, 0.5, 0.0) > GpSampler::ei(1.0, 0.5, 0.0));
        assert!(GpSampler::ei(0.0, 1.0, 0.0) > 0.0);
        assert_eq!(GpSampler::ei(1.0, 0.0, 0.0), 0.0);
    }

    #[test]
    fn concentrates_near_minimum() {
        let trials: Vec<FrozenTrial> = (0..20)
            .map(|i| quad_trial(i, (i as f64) / 19.0))
            .collect();
        let s = GpSampler::new(0);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        let space = s.infer_relative_search_space(&ctx);
        assert_eq!(space.len(), 1);
        let mut hits = 0;
        for i in 0..20 {
            let rel = s.sample_relative(&ctx, 20 + i, &space);
            let x = rel["x"];
            if (x - 0.3).abs() < 0.15 {
                hits += 1;
            }
        }
        // uniform would land ~30% of the time in ±0.15
        assert!(hits >= 12, "hits={hits}");
    }

    #[test]
    fn respects_direction_maximize() {
        // objective = -(x-0.3)^2, maximize: same optimum
        let d = Distribution::float(0.0, 1.0);
        let trials: Vec<FrozenTrial> = (0..20)
            .map(|i| {
                let x = (i as f64) / 19.0;
                completed_trial(
                    i,
                    &[("x", d.clone(), ParamValue::Float(x))],
                    -(x - 0.3) * (x - 0.3),
                )
            })
            .collect();
        let s = GpSampler::new(1);
        let ctx = StudyContext::new(StudyDirection::Maximize, &trials);
        let space = s.infer_relative_search_space(&ctx);
        let mut hits = 0;
        for i in 0..20 {
            let rel = s.sample_relative(&ctx, 20 + i, &space);
            if (rel["x"] - 0.3).abs() < 0.15 {
                hits += 1;
            }
        }
        assert!(hits >= 12, "hits={hits}");
    }

    #[test]
    fn startup_defers_to_fallback() {
        let s = GpSampler::new(2);
        let trials: Vec<FrozenTrial> = (0..2).map(|i| quad_trial(i, 0.5)).collect();
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        assert!(s.infer_relative_search_space(&ctx).is_empty());
    }
}
