//! Uniform random sampling — the Fig 9/11 baseline.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::core::Distribution;
use crate::sampler::{Sampler, SearchSpace, StudyContext};
use crate::util::rng::Pcg64;

/// Samples every parameter independently and uniformly (log-uniform for
/// log-scaled distributions, uniform over categories for categoricals).
pub struct RandomSampler {
    rng: Mutex<Pcg64>,
}

impl RandomSampler {
    pub fn new(seed: u64) -> Self {
        RandomSampler { rng: Mutex::new(Pcg64::new(seed)) }
    }

    /// Registry constructor (spec `random`) — no knobs.
    pub fn from_config(
        _cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        Ok(RandomSampler::new(seed))
    }

    /// Uniform draw in a distribution's internal space.
    pub fn draw(rng: &mut Pcg64, dist: &Distribution) -> f64 {
        match dist {
            Distribution::Categorical { choices } => rng.index(choices.len()) as f64,
            _ => {
                let (lo, hi) = dist.internal_range();
                rng.uniform_range(lo, hi)
            }
        }
    }
}

impl Sampler for RandomSampler {
    fn infer_relative_search_space(&self, _ctx: &StudyContext<'_>) -> SearchSpace {
        SearchSpace::new() // purely independent
    }

    fn sample_relative(
        &self,
        _ctx: &StudyContext<'_>,
        _trial_number: u64,
        _space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        BTreeMap::new()
    }

    fn sample_independent(
        &self,
        _ctx: &StudyContext<'_>,
        _trial_number: u64,
        _name: &str,
        dist: &Distribution,
    ) -> f64 {
        Self::draw(&mut self.rng.lock().unwrap(), dist)
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ParamValue, StudyDirection};

    fn ctx<'a>(trials: &'a [crate::core::FrozenTrial]) -> StudyContext<'a> {
        StudyContext::new(StudyDirection::Minimize, trials)
    }

    #[test]
    fn uniform_within_bounds() {
        let s = RandomSampler::new(0);
        let d = Distribution::float(-2.0, 3.0);
        for i in 0..1000 {
            let v = s.sample_independent(&ctx(&[]), i, "x", &d);
            assert!((-2.0..=3.0).contains(&v));
        }
    }

    #[test]
    fn log_uniform_is_log_spaced() {
        let s = RandomSampler::new(1);
        let d = Distribution::log_float(1e-6, 1.0);
        let mut below_1e3 = 0;
        let n = 4000;
        for i in 0..n {
            let internal = s.sample_independent(&ctx(&[]), i, "x", &d);
            if let ParamValue::Float(v) = d.external(internal) {
                if v < 1e-3 {
                    below_1e3 += 1;
                }
            }
        }
        // log-uniform => half the mass below the geometric midpoint 1e-3
        let frac = below_1e3 as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn categorical_covers_choices() {
        let s = RandomSampler::new(2);
        let d = Distribution::categorical(vec!["a", "b", "c"]);
        let mut seen = [false; 3];
        for i in 0..200 {
            let v = s.sample_independent(&ctx(&[]), i, "c", &d);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn relative_space_empty() {
        let s = RandomSampler::new(3);
        assert!(s.infer_relative_search_space(&ctx(&[])).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let d = Distribution::float(0.0, 1.0);
        let a: Vec<f64> = {
            let s = RandomSampler::new(42);
            (0..10).map(|i| s.sample_independent(&ctx(&[]), i, "x", &d)).collect()
        };
        let b: Vec<f64> = {
            let s = RandomSampler::new(42);
            (0..10).map(|i| s.sample_independent(&ctx(&[]), i, "x", &d)).collect()
        };
        assert_eq!(a, b);
    }
}
