//! TPE+CMA-ES mixture — the headline sampler of §5.1 / Fig 9.
//!
//! "For TPE+CMA-ES, we used TPE for the first 40 steps and used CMA-ES
//! for the rest." TPE's independent sampling handles the early
//! exploration and any parameter outside the relational subspace;
//! after the switch point, CMA-ES jointly samples the intersection
//! search space.

use std::collections::BTreeMap;

use crate::core::{Distribution, TrialState};
use crate::sampler::{CmaEsSampler, Sampler, SearchSpace, StudyContext, TpeSampler};

/// The mixture sampler.
pub struct TpeCmaEsSampler {
    tpe: TpeSampler,
    cmaes: CmaEsSampler,
    /// Completed-trial count at which CMA-ES takes over (paper: 40).
    pub n_switch: usize,
}

impl TpeCmaEsSampler {
    pub fn new(seed: u64) -> Self {
        Self::with_switch(seed, 40)
    }

    pub fn with_switch(seed: u64, n_switch: usize) -> Self {
        TpeCmaEsSampler {
            tpe: TpeSampler::new(seed),
            cmaes: CmaEsSampler::new(seed ^ 0xc0a),
            n_switch,
        }
    }

    /// Registry constructor (spec `tpe+cmaes:n_switch=60`).
    pub fn from_config(
        cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        let n_switch = cfg.get_usize("n_switch")?.unwrap_or(40);
        Ok(Self::with_switch(seed, n_switch))
    }

    fn n_complete(ctx: &StudyContext<'_>) -> usize {
        ctx.trials
            .iter()
            .filter(|t| t.state == TrialState::Complete)
            .count()
    }
}

impl Sampler for TpeCmaEsSampler {
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace {
        if Self::n_complete(ctx) < self.n_switch {
            SearchSpace::new() // TPE phase: independent sampling only
        } else {
            self.cmaes.infer_relative_search_space(ctx)
        }
    }

    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        if space.is_empty() {
            return BTreeMap::new();
        }
        self.cmaes.sample_relative(ctx, trial_number, space)
    }

    fn sample_independent(
        &self,
        ctx: &StudyContext<'_>,
        trial_number: u64,
        name: &str,
        dist: &Distribution,
    ) -> f64 {
        // TPE covers everything the relational phase doesn't.
        self.tpe.sample_independent(ctx, trial_number, name, dist)
    }

    fn name(&self) -> &'static str {
        "tpe+cmaes"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{FrozenTrial, ParamValue, StudyDirection};
    use crate::sampler::testutil::completed_trial;

    fn history(n: usize) -> Vec<FrozenTrial> {
        let d = Distribution::float(-5.0, 5.0);
        let mut rng = crate::util::rng::Pcg64::new(9);
        (0..n)
            .map(|i| {
                let x = rng.uniform_range(-5.0, 5.0);
                completed_trial(
                    i as u64,
                    &[("x", d.clone(), ParamValue::Float(x))],
                    x * x,
                )
            })
            .collect()
    }

    #[test]
    fn tpe_phase_has_no_relative_space() {
        let s = TpeCmaEsSampler::new(0);
        let trials = history(39);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        assert!(s.infer_relative_search_space(&ctx).is_empty());
    }

    #[test]
    fn cmaes_phase_activates_after_switch() {
        let s = TpeCmaEsSampler::new(0);
        let trials = history(45);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        let space = s.infer_relative_search_space(&ctx);
        assert_eq!(space.len(), 1);
        let rel = s.sample_relative(&ctx, 45, &space);
        assert!(rel.contains_key("x"));
        assert!((-5.0..=5.0).contains(&rel["x"]));
    }

    #[test]
    fn custom_switch_point() {
        let s = TpeCmaEsSampler::with_switch(0, 5);
        let trials = history(6);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        assert!(!s.infer_relative_search_space(&ctx).is_empty());
    }

    #[test]
    fn independent_falls_back_to_tpe() {
        let s = TpeCmaEsSampler::new(1);
        let d = Distribution::float(-5.0, 5.0);
        let trials = history(60);
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials);
        // concentration check (TPE behaviour)
        let mut near = 0;
        for i in 0..60 {
            let v = s.sample_independent(&ctx, 60 + i, "x", &d);
            if v.abs() < 1.5 {
                near += 1;
            }
        }
        assert!(near > 35, "near={near}");
    }
}
