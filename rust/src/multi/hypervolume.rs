//! Exact hypervolume indicator for 1–3 objectives.
//!
//! Hypervolume of a point set `P` w.r.t. a reference point `r` (all in
//! minimization-loss space): the Lebesgue measure of
//! `⋃_{p ∈ P} [p, r]` — the region dominated by at least one point and
//! bounded by the reference. It is the standard strictly-Pareto-compliant
//! quality indicator, which is what makes the NSGA-II-beats-random
//! acceptance gate of `rust/tests/moo.rs` meaningful.
//!
//! * d=1 — `r - min(p)`.
//! * d=2 — WFG-style sweep: sort the nondominated set ascending by the
//!   first loss (second loss then descends) and sum the disjoint strips.
//! * d=3 — slicing: sweep the third axis over the points' distinct
//!   values; each slab contributes `(z_next - z_k) × HV2(points with
//!   loss₂ ≤ z_k)`.
//!
//! Points that do not strictly dominate the reference point (including
//! any with a NaN loss, which ranks worst) contribute nothing and are
//! filtered up front. Higher dimensions need an exponential-in-d
//! algorithm (WFG/HBDA) and return an error rather than a wrong number.

use crate::core::OptunaError;
use crate::multi::dominance::dominates;
use crate::sampler::kernels::dominance as dkern;
use crate::util::stats::nan_max_cmp;

/// Exact hypervolume of `points` (minimization losses) w.r.t. `reference`.
/// Supports 1, 2 or 3 objectives; every point must have the reference's
/// length. Returns 0.0 when no point strictly dominates the reference.
pub fn hypervolume(points: &[Vec<f64>], reference: &[f64]) -> Result<f64, OptunaError> {
    let d = reference.len();
    if d == 0 || d > 3 {
        return Err(OptunaError::MultiObjective(format!(
            "exact hypervolume supports 1-3 objectives, got {d}"
        )));
    }
    for p in points {
        if p.len() != d {
            return Err(OptunaError::MultiObjective(format!(
                "hypervolume point has {} objectives, reference has {d}",
                p.len()
            )));
        }
    }
    // only points strictly inside the reference box contribute volume
    // (NaN losses fail the < comparison and drop out here)
    let inside: Vec<&[f64]> = points
        .iter()
        .map(|p| p.as_slice())
        .filter(|p| p.iter().zip(reference).all(|(x, r)| x < r))
        .collect();
    Ok(match d {
        1 => inside
            .iter()
            .map(|p| reference[0] - p[0])
            .fold(0.0, f64::max),
        2 => hv2(&inside, reference[0], reference[1]),
        _ => hv3(&inside, reference),
    })
}

/// 2-d sweep over the nondominated subset. `points` are strictly inside
/// the (r0, r1) box.
///
/// The nondominated filter runs on flat `u64` key columns
/// ([`crate::sampler::kernels::dominance`]) — one integer compare per
/// objective instead of a `nan_max_cmp` match — keeping the selected
/// subset, its order, and therefore every float in the strip sum
/// bit-identical to the scalar [`pareto_filter_scalar`] route.
fn hv2(points: &[&[f64]], r0: f64, r1: f64) -> f64 {
    let mut front: Vec<&[f64]> = match dkern::FlatKeys::from_slices(points) {
        Some(flat) => dkern::pareto_filter_indices(&flat)
            .into_iter()
            .map(|i| points[i])
            .collect(),
        None => pareto_filter_scalar(points), // ragged — cannot happen from hypervolume()
    };
    // ascending loss 0 ⇒ (strictly) descending loss 1 on a nondominated set
    front.sort_by(|a, b| nan_max_cmp(&a[0], &b[0]));
    let mut hv = 0.0;
    let mut prev1 = r1;
    for p in front {
        hv += (r0 - p[0]) * (prev1 - p[1]);
        prev1 = p[1];
    }
    hv
}

/// 3-d slicing along the third axis. The per-slab active set reuses one
/// buffer — the old per-slab `Vec` collect made hv3 allocation-bound at
/// NSGA-II population sizes.
fn hv3(points: &[&[f64]], reference: &[f64]) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let mut zs: Vec<f64> = points.iter().map(|p| p[2]).collect();
    zs.sort_by(nan_max_cmp);
    zs.dedup();
    let mut hv = 0.0;
    let mut active: Vec<&[f64]> = Vec::with_capacity(points.len());
    for (k, &z) in zs.iter().enumerate() {
        let z_next = zs.get(k + 1).copied().unwrap_or(reference[2]);
        let slab = z_next - z;
        if slab <= 0.0 {
            continue;
        }
        active.clear();
        active.extend(points.iter().copied().filter(|p| p[2] <= z).map(|p| &p[..2]));
        hv += slab * hv2(&active, reference[0], reference[1]);
    }
    hv
}

/// Drop dominated (and duplicate) points — the sweeps assume a
/// mutually-nondominated input. Scalar oracle for the key-based filter
/// in [`hv2`] (differential-tested below).
fn pareto_filter_scalar<'a>(points: &[&'a [f64]]) -> Vec<&'a [f64]> {
    let mut kept: Vec<&[f64]> = Vec::with_capacity(points.len());
    'outer: for &p in points {
        for &q in points {
            if !std::ptr::eq(p, q) && dominates(q, p) {
                continue 'outer;
            }
        }
        if kept
            .iter()
            .any(|&k| k.iter().zip(p).all(|(a, b)| nan_max_cmp(a, b) == std::cmp::Ordering::Equal))
        {
            continue; // exact duplicate already counted
        }
        kept.push(p);
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::quickcheck::check;

    fn hv(points: &[Vec<f64>], r: &[f64]) -> f64 {
        hypervolume(points, r).unwrap()
    }

    /// Brute-force HV by coordinate compression: a grid cell is covered
    /// iff some point is ≤ its lower corner in every objective. Exact for
    /// any dimension; O(n^(d+1)) — test-only.
    fn hv_brute(points: &[Vec<f64>], reference: &[f64]) -> f64 {
        let d = reference.len();
        let inside: Vec<&Vec<f64>> = points
            .iter()
            .filter(|p| p.iter().zip(reference).all(|(x, r)| x < r))
            .collect();
        if inside.is_empty() {
            return 0.0;
        }
        // per-axis sorted breakpoints: point coords + reference
        let mut axes: Vec<Vec<f64>> = Vec::with_capacity(d);
        for m in 0..d {
            let mut xs: Vec<f64> = inside.iter().map(|p| p[m]).collect();
            xs.push(reference[m]);
            xs.sort_by(nan_max_cmp);
            xs.dedup();
            axes.push(xs);
        }
        // iterate all cells via mixed-radix counter over axis intervals
        let radix: Vec<usize> = axes.iter().map(|a| a.len() - 1).collect();
        if radix.iter().any(|&r| r == 0) {
            return 0.0;
        }
        let mut idx = vec![0usize; d];
        let mut total = 0.0;
        loop {
            let corner: Vec<f64> = (0..d).map(|m| axes[m][idx[m]]).collect();
            if inside
                .iter()
                .any(|p| p.iter().zip(&corner).all(|(a, b)| a <= b))
            {
                let vol: f64 = (0..d).map(|m| axes[m][idx[m] + 1] - axes[m][idx[m]]).product();
                total += vol;
            }
            // increment counter
            let mut m = 0;
            loop {
                idx[m] += 1;
                if idx[m] < radix[m] {
                    break;
                }
                idx[m] = 0;
                m += 1;
                if m == d {
                    return total;
                }
            }
        }
    }

    #[test]
    fn one_point_is_its_box() {
        assert_eq!(hv(&[vec![1.0, 1.0]], &[2.0, 3.0]), 2.0);
        assert_eq!(hv(&[vec![0.5]], &[2.0]), 1.5);
        assert_eq!(hv(&[vec![1.0, 1.0, 1.0]], &[2.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn union_not_sum_in_2d() {
        let pts = vec![vec![1.0, 2.0], vec![2.0, 1.0]];
        // boxes 2x1 and 1x2 overlapping in 1x1
        assert_eq!(hv(&pts, &[3.0, 3.0]), 3.0);
        // dominated and duplicate points change nothing
        let with_noise = vec![
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![2.5, 2.5],
            vec![1.0, 2.0],
        ];
        assert_eq!(hv(&with_noise, &[3.0, 3.0]), 3.0);
    }

    #[test]
    fn outside_reference_contributes_nothing() {
        assert_eq!(hv(&[], &[1.0, 1.0]), 0.0);
        assert_eq!(hv(&[vec![2.0, 0.0]], &[1.0, 1.0]), 0.0, "on/over the edge");
        assert_eq!(hv(&[vec![1.0, 0.0]], &[1.0, 1.0]), 0.0, "boundary is exclusive");
        assert_eq!(hv(&[vec![f64::NAN, 0.0]], &[1.0, 1.0]), 0.0, "NaN loss drops out");
    }

    #[test]
    fn three_d_slicing_hand_case() {
        // two boxes: [1,2]^3 from (1,1,1) and a thin slab from (0,0,1.5)
        let pts = vec![vec![1.0, 1.0, 1.0], vec![0.0, 0.0, 1.5]];
        // box1 = 1, box2 = 2*2*0.5 = 2, overlap = 1*1*0.5 = 0.5
        assert!((hv(&pts, &[2.0, 2.0, 2.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn dimension_errors() {
        assert!(hypervolume(&[vec![0.0; 4]], &[1.0; 4]).is_err());
        assert!(hypervolume(&[], &[]).is_err());
        assert!(hypervolume(&[vec![0.0, 0.0]], &[1.0]).is_err());
    }

    /// The key-based nondominated filter must select the identical
    /// subset, in the identical order, as the scalar oracle — the strip
    /// sums downstream are only bit-stable if this holds.
    #[test]
    fn property_key_filter_equals_scalar_filter() {
        check("hv_filter_equiv", 60, |rng| {
            let n = rng.int_range(0, 20) as usize;
            // coarse half-grid: duplicates and dominance ties are common
            let points: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..2).map(|_| rng.int_range(0, 5) as f64 / 2.0).collect())
                .collect();
            let refs: Vec<&[f64]> = points.iter().map(|p| p.as_slice()).collect();
            let scalar = pareto_filter_scalar(&refs);
            let flat = dkern::FlatKeys::from_slices(&refs).unwrap();
            let keyed: Vec<&[f64]> = dkern::pareto_filter_indices(&flat)
                .into_iter()
                .map(|i| refs[i])
                .collect();
            prop_assert!(
                keyed == scalar,
                "filter diverged: keyed={keyed:?} scalar={scalar:?} input={points:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn property_matches_brute_force_2d_and_3d() {
        check("hv_vs_brute", 40, |rng| {
            let d = rng.int_range(2, 3) as usize; // exact path covers d <= 3
            let n = rng.int_range(0, 12) as usize;
            // coarse grid coords stress ties, duplicates and boundary hits
            let points: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.int_range(0, 5) as f64 / 2.0).collect())
                .collect();
            let reference = vec![2.0; d];
            let fast = hypervolume(&points, &reference).map_err(|e| e.to_string())?;
            let brute = hv_brute(&points, &reference);
            prop_assert!(
                (fast - brute).abs() < 1e-9,
                "d={d} fast={fast} brute={brute} points={points:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn property_monotone_under_adding_points() {
        check("hv_monotone", 30, |rng| {
            let d = rng.int_range(2, 3) as usize;
            let mut points: Vec<Vec<f64>> = Vec::new();
            let reference = vec![1.0; d];
            let mut prev = 0.0;
            for _ in 0..10 {
                points.push((0..d).map(|_| rng.uniform()).collect());
                let now = hypervolume(&points, &reference).map_err(|e| e.to_string())?;
                prop_assert!(now >= prev - 1e-12, "HV shrank: {prev} -> {now}");
                prev = now;
            }
            Ok(())
        });
    }
}
