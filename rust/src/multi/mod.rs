//! Multi-objective optimization — vector objectives end to end.
//!
//! The paper's criterion (3) asks for a "versatile architecture that can
//! be deployed for various purposes"; accuracy-vs-latency and
//! quality-vs-size tuning are the canonical purposes a scalar-objective
//! framework cannot express. This subsystem opens that workload class:
//!
//! * [`dominance`] — Pareto dominance over direction-normalized losses,
//!   NaN-safe via [`crate::util::stats::nan_max_cmp`] (a diverged
//!   objective ranks worst, it never panics a comparison), plus Deb's
//!   constrained dominance ([`dominates_constrained`]: feasible beats
//!   infeasible, infeasible compared by [`total_violation`]);
//! * [`nds`] — fast nondominated sorting (Deb's domination-count
//!   algorithm, O(M·N²)) and crowding distance, the selection machinery
//!   of NSGA-II and of [`crate::study::Study::best_trials`], with a
//!   constraint-aware variant ([`nondominated_sort_constrained`]);
//! * [`NsgaIiSampler`] — NSGA-II as a drop-in
//!   [`crate::sampler::Sampler`]: binary tournament selection on
//!   (rank, crowding), simulated-binary crossover and polynomial mutation
//!   over the intersection search space, falling back to uniform random
//!   sampling until `population_size` trials have completed; with
//!   [`NsgaIiConfig::constraints`] set, selection runs under Deb's rules
//!   over `Trial::report_constraints` values;
//! * [`hypervolume()`] — exact hypervolume indicator for
//!   1–3 objectives (sweep for d=2, slicing over the third axis for
//!   d=3), the quality number `BENCH_moo.json` tracks and
//!   [`crate::study::Study::hypervolume`] exposes.
//!
//! Everything here works on plain `&[Vec<f64>]` objective matrices plus a
//! `&[StudyDirection]` vector, so it is reusable outside the study layer
//! (benches and the CLI `pareto` command call it directly). Trials enter
//! the subsystem through [`crate::core::FrozenTrial::objective_values`],
//! which folds pre-multi scalar records into 1-vectors.

pub mod dominance;
pub mod hypervolume;
pub mod nds;
mod nsga2;

pub use dominance::{dominates, dominates_constrained, total_violation};
pub use hypervolume::hypervolume;
pub use nds::{
    crowding_distance, nondominated_sort, nondominated_sort_constrained,
    nondominated_sort_constrained_scalar, nondominated_sort_scalar,
};
pub use nsga2::{NsgaIiConfig, NsgaIiSampler};

use crate::core::StudyDirection;

/// Direction-normalize an objective vector to minimization losses
/// (`loss[i] = min_sign(directions[i]) * values[i]`): the canonical space
/// every routine in this module compares in.
pub fn to_losses(values: &[f64], directions: &[StudyDirection]) -> Vec<f64> {
    debug_assert_eq!(values.len(), directions.len());
    values
        .iter()
        .zip(directions)
        .map(|(v, d)| d.min_sign() * v)
        .collect()
}
