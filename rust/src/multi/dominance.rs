//! Pareto dominance over minimization losses.

use std::cmp::Ordering;

use crate::util::stats::nan_max_cmp;

/// True iff loss vector `a` Pareto-dominates `b`: no worse in every
/// objective and strictly better in at least one. Both vectors are
/// minimization losses (see [`crate::multi::to_losses`]) of equal length.
///
/// NaN-safe per [`nan_max_cmp`]: a NaN loss is the worst possible value
/// in its objective, so a vector with a NaN component can only dominate
/// vectors that are NaN there too — and equal-NaN components compare
/// equal instead of poisoning the comparison.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        match nan_max_cmp(x, y) {
            Ordering::Greater => return false,
            Ordering::Less => strictly_better = true,
            Ordering::Equal => {}
        }
    }
    strictly_better
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_and_weak_cases() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]), "equal in one, better in other");
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal vectors do not dominate");
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]), "trade-off: incomparable");
        assert!(!dominates(&[2.0, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn single_objective_reduces_to_less_than() {
        assert!(dominates(&[1.0], &[2.0]));
        assert!(!dominates(&[2.0], &[1.0]));
        assert!(!dominates(&[1.0], &[1.0]));
    }

    #[test]
    fn nan_ranks_worst_not_poisonous() {
        // NaN component: can be dominated, cannot dominate a finite value
        assert!(dominates(&[1.0, 1.0], &[1.0, f64::NAN]));
        assert!(!dominates(&[1.0, f64::NAN], &[1.0, 1.0]));
        // equal NaNs compare equal: the finite objective decides
        assert!(dominates(&[1.0, f64::NAN], &[2.0, f64::NAN]));
        assert!(!dominates(&[f64::NAN, f64::NAN], &[f64::NAN, f64::NAN]));
    }
}
