//! Pareto dominance over minimization losses.

use std::cmp::Ordering;

use crate::util::stats::nan_max_cmp;

/// True iff loss vector `a` Pareto-dominates `b`: no worse in every
/// objective and strictly better in at least one. Both vectors are
/// minimization losses (see [`crate::multi::to_losses`]) of equal length.
///
/// NaN-safe per [`nan_max_cmp`]: a NaN loss is the worst possible value
/// in its objective, so a vector with a NaN component can only dominate
/// vectors that are NaN there too — and equal-NaN components compare
/// equal instead of poisoning the comparison.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        match nan_max_cmp(x, y) {
            Ordering::Greater => return false,
            Ordering::Less => strictly_better = true,
            Ordering::Equal => {}
        }
    }
    strictly_better
}

/// Total violation of a constraint vector: `Σ max(0, c_i)`, with NaN
/// components counting as +∞ (a diverged constraint evaluation is the
/// worst possible outcome, mirroring NaN losses under [`nan_max_cmp`]).
/// Zero iff the vector is feasible; empty vectors are feasible.
pub fn total_violation(constraints: &[f64]) -> f64 {
    constraints
        .iter()
        .map(|&c| if c.is_nan() { f64::INFINITY } else { c.max(0.0) })
        .sum()
}

/// Constrained dominance — Deb's rules (Deb et al. 2002 §VI):
///
/// 1. a feasible solution dominates any infeasible one;
/// 2. two infeasible solutions are compared by total violation alone
///    (smaller dominates);
/// 3. two feasible solutions fall back to Pareto [`dominates`].
///
/// `a_viol`/`b_viol` are [`total_violation`] values (0 = feasible).
pub fn dominates_constrained(a: &[f64], a_viol: f64, b: &[f64], b_viol: f64) -> bool {
    let a_feasible = a_viol <= 0.0;
    let b_feasible = b_viol <= 0.0;
    match (a_feasible, b_feasible) {
        (true, false) => true,
        (false, true) => false,
        (false, false) => a_viol < b_viol,
        (true, true) => dominates(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strict_and_weak_cases() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]), "equal in one, better in other");
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal vectors do not dominate");
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]), "trade-off: incomparable");
        assert!(!dominates(&[2.0, 2.0], &[1.0, 1.0]));
    }

    #[test]
    fn single_objective_reduces_to_less_than() {
        assert!(dominates(&[1.0], &[2.0]));
        assert!(!dominates(&[2.0], &[1.0]));
        assert!(!dominates(&[1.0], &[1.0]));
    }

    #[test]
    fn nan_ranks_worst_not_poisonous() {
        // NaN component: can be dominated, cannot dominate a finite value
        assert!(dominates(&[1.0, 1.0], &[1.0, f64::NAN]));
        assert!(!dominates(&[1.0, f64::NAN], &[1.0, 1.0]));
        // equal NaNs compare equal: the finite objective decides
        assert!(dominates(&[1.0, f64::NAN], &[2.0, f64::NAN]));
        assert!(!dominates(&[f64::NAN, f64::NAN], &[f64::NAN, f64::NAN]));
    }

    #[test]
    fn violation_sums_positive_parts() {
        assert_eq!(total_violation(&[]), 0.0);
        assert_eq!(total_violation(&[-3.0, 0.0]), 0.0);
        assert_eq!(total_violation(&[-3.0, 1.0, 0.5]), 1.5);
        assert_eq!(total_violation(&[f64::NAN]), f64::INFINITY);
    }

    #[test]
    fn deb_rules() {
        // rule 1: any feasible beats any infeasible, regardless of losses
        assert!(dominates_constrained(&[9.0, 9.0], 0.0, &[1.0, 1.0], 0.1));
        assert!(!dominates_constrained(&[1.0, 1.0], 0.1, &[9.0, 9.0], 0.0));
        // rule 2: infeasible vs infeasible — violation only
        assert!(dominates_constrained(&[9.0, 9.0], 0.1, &[1.0, 1.0], 0.2));
        assert!(!dominates_constrained(&[1.0, 1.0], 0.2, &[9.0, 9.0], 0.1));
        assert!(!dominates_constrained(&[1.0, 1.0], 0.2, &[9.0, 9.0], 0.2));
        // rule 3: feasible vs feasible — plain Pareto
        assert!(dominates_constrained(&[1.0, 1.0], 0.0, &[2.0, 2.0], 0.0));
        assert!(!dominates_constrained(&[1.0, 3.0], 0.0, &[2.0, 2.0], 0.0));
        // NaN violation never dominates, is dominated by feasible
        assert!(dominates_constrained(&[9.0], 0.0, &[1.0], f64::NAN));
        assert!(!dominates_constrained(&[1.0], f64::NAN, &[9.0], 0.1));
    }
}
