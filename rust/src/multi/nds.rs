//! Fast nondominated sorting and crowding distance (Deb et al., the
//! NSGA-II selection machinery).

use std::cmp::Ordering;

use crate::multi::dominance::{dominates, dominates_constrained};
use crate::sampler::kernels::dominance as dkern;
use crate::util::stats::nan_max_cmp;

/// Partition loss vectors into Pareto fronts: `fronts[0]` is the
/// nondominated set, `fronts[k]` is nondominated once fronts `0..k` are
/// removed. Every input index appears in exactly one front. Deb's
/// domination-count algorithm: O(M·N²) comparisons, O(N²) worst-case
/// memory for the dominated-by lists.
///
/// All vectors must share one length; losses are minimization-normalized
/// (see [`crate::multi::to_losses`]) and NaN-safe per the dominance
/// comparator.
///
/// Rectangular inputs take the vectorized kernel (`u64`-key compares +
/// bit-packed peeling, [`crate::sampler::kernels::dominance`]), which
/// produces front-for-front identical output to
/// [`nondominated_sort_scalar`]; ragged inputs fall back to the scalar
/// oracle.
pub fn nondominated_sort(losses: &[Vec<f64>]) -> Vec<Vec<usize>> {
    match dkern::FlatKeys::from_rows(losses) {
        Some(flat) => dkern::sort_fronts(&flat, None),
        None => nondominated_sort_scalar(losses),
    }
}

/// Scalar-oracle [`nondominated_sort`]: per-pair [`dominates`] calls and
/// `Vec`-list peeling. Kept public, like `SingleMutexStorage`, as the
/// differential baseline for the kernel path (`rust/tests/kernel_equiv.rs`)
/// and for ragged inputs.
pub fn nondominated_sort_scalar(losses: &[Vec<f64>]) -> Vec<Vec<usize>> {
    sort_by_dominance(losses.len(), |i, j| dominates(&losses[i], &losses[j]))
}

/// [`nondominated_sort`] under Deb's constrained dominance:
/// `violations[i]` is the [`crate::multi::total_violation`] of trial `i`
/// (0 = feasible). When feasible solutions exist, front 0 is drawn from
/// them exclusively — every infeasible solution is dominated by rule 1.
pub fn nondominated_sort_constrained(
    losses: &[Vec<f64>],
    violations: &[f64],
) -> Vec<Vec<usize>> {
    debug_assert_eq!(losses.len(), violations.len());
    match dkern::FlatKeys::from_rows(losses) {
        Some(flat) if violations.len() == losses.len() => {
            dkern::sort_fronts(&flat, Some(violations))
        }
        _ => nondominated_sort_constrained_scalar(losses, violations),
    }
}

/// Scalar oracle for [`nondominated_sort_constrained`].
pub fn nondominated_sort_constrained_scalar(
    losses: &[Vec<f64>],
    violations: &[f64],
) -> Vec<Vec<usize>> {
    sort_by_dominance(losses.len(), |i, j| {
        dominates_constrained(&losses[i], violations[i], &losses[j], violations[j])
    })
}

/// Deb's domination-count front peeling over an arbitrary dominance
/// relation (must be a strict partial order — irreflexive, transitive).
fn sort_by_dominance(n: usize, dom: impl Fn(usize, usize) -> bool) -> Vec<Vec<usize>> {
    if n == 0 {
        return Vec::new();
    }
    // dominated[i] = indices i dominates; count[i] = how many dominate i
    let mut dominated: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dom(i, j) {
                dominated[i].push(j);
                count[j] += 1;
            } else if dom(j, i) {
                dominated[j].push(i);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated[i] {
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance of each member of one front (indices into `losses`):
/// boundary points per objective get `f64::INFINITY`, interior points sum
/// the normalized gap between their neighbors. Larger = lonelier =
/// preferred at truncation time. Degenerate objectives (zero or NaN
/// spread) contribute nothing.
pub fn crowding_distance(losses: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let n = front.len();
    let mut dist = vec![0.0f64; n];
    if n == 0 {
        return dist;
    }
    let n_obj = losses[front[0]].len();
    let mut order: Vec<usize> = (0..n).collect(); // positions within `front`
    for m in 0..n_obj {
        order.sort_by(|&a, &b| nan_max_cmp(&losses[front[a]][m], &losses[front[b]][m]));
        let lo = losses[front[order[0]]][m];
        let hi = losses[front[order[n - 1]]][m];
        let spread = hi - lo;
        dist[order[0]] = f64::INFINITY;
        dist[order[n - 1]] = f64::INFINITY;
        if !(spread > 0.0) || !spread.is_finite() {
            continue; // all equal (or NaN spread): no interior information
        }
        for w in 1..n - 1 {
            let gap = losses[front[order[w + 1]]][m] - losses[front[order[w - 1]]][m];
            if gap.is_finite() {
                dist[order[w]] += gap / spread;
            }
        }
    }
    dist
}

/// Sort key for NSGA-II truncation/tournaments: lower front rank wins,
/// ties broken by larger crowding distance.
pub fn rank_crowding_cmp(rank_a: usize, crowd_a: f64, rank_b: usize, crowd_b: f64) -> Ordering {
    rank_a
        .cmp(&rank_b)
        .then_with(|| nan_max_cmp(&crowd_a, &crowd_b).reverse())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::quickcheck::check;

    #[test]
    fn hand_built_fronts() {
        // front 0: (1,4), (2,2), (4,1); front 1: (3,3), (2,5); front 2: (5,5)
        let losses = vec![
            vec![1.0, 4.0],
            vec![3.0, 3.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![2.0, 5.0],
            vec![5.0, 5.0],
        ];
        let fronts = nondominated_sort(&losses);
        assert_eq!(fronts.len(), 3);
        let mut f0 = fronts[0].clone();
        f0.sort_unstable();
        assert_eq!(f0, vec![0, 2, 3]);
        let mut f1 = fronts[1].clone();
        f1.sort_unstable();
        assert_eq!(f1, vec![1, 4]);
        assert_eq!(fronts[2], vec![5]);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(nondominated_sort(&[]).is_empty());
        let one = nondominated_sort(&[vec![1.0, 2.0]]);
        assert_eq!(one, vec![vec![0]]);
    }

    #[test]
    fn crowding_boundaries_infinite_interior_ordered() {
        // colinear front: interior spacing should reward the lonely point
        let losses = vec![
            vec![0.0, 10.0],
            vec![1.0, 9.0],
            vec![2.0, 8.0],
            vec![9.0, 1.0], // far from its neighbors
            vec![10.0, 0.0],
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&losses, &front);
        assert!(d[0].is_infinite() && d[4].is_infinite());
        assert!(d[3] > d[1], "isolated interior point must be lonelier: {d:?}");
        assert!(d[1] > 0.0 && d[2] > 0.0);
    }

    #[test]
    fn crowding_degenerate_objective_is_noop() {
        let losses = vec![vec![1.0, 5.0], vec![2.0, 5.0], vec![3.0, 5.0]];
        let front: Vec<usize> = (0..3).collect();
        let d = crowding_distance(&losses, &front);
        // objective 1 has zero spread; objective 0 still ranks them
        assert!(d[0].is_infinite() && d[2].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn rank_then_crowding() {
        assert_eq!(rank_crowding_cmp(0, 0.1, 1, 9.9), Ordering::Less);
        assert_eq!(rank_crowding_cmp(1, 0.5, 1, 0.2), Ordering::Less, "lonelier wins ties");
        assert_eq!(rank_crowding_cmp(1, 0.2, 1, 0.5), Ordering::Greater);
        assert_eq!(rank_crowding_cmp(2, f64::INFINITY, 2, 1.0), Ordering::Less);
    }

    #[test]
    fn constrained_sort_front0_is_feasible() {
        // three feasible (one dominated), two infeasible with different
        // violations — fronts must be: feasible nondominated, dominated
        // feasible, then infeasible by ascending violation
        let losses = vec![
            vec![1.0, 4.0], // feasible, front 0
            vec![4.0, 1.0], // feasible, front 0
            vec![5.0, 5.0], // feasible but dominated -> front 1
            vec![0.0, 0.0], // best losses but violation 2.0 -> front 3
            vec![9.0, 9.0], // violation 1.0 -> front 2
        ];
        let viol = vec![0.0, 0.0, 0.0, 2.0, 1.0];
        let fronts = nondominated_sort_constrained(&losses, &viol);
        let mut f0 = fronts[0].clone();
        f0.sort_unstable();
        assert_eq!(f0, vec![0, 1]);
        assert_eq!(fronts[1], vec![2]);
        assert_eq!(fronts[2], vec![4]);
        assert_eq!(fronts[3], vec![3], "great losses cannot rescue infeasibility");
    }

    #[test]
    fn constrained_sort_all_feasible_matches_plain() {
        let losses = vec![
            vec![1.0, 4.0],
            vec![3.0, 3.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
        ];
        let viol = vec![0.0; 4];
        assert_eq!(
            nondominated_sort_constrained(&losses, &viol),
            nondominated_sort(&losses)
        );
    }

    #[test]
    fn constrained_sort_all_infeasible_orders_by_violation() {
        let losses = vec![vec![1.0], vec![2.0], vec![3.0]];
        let viol = vec![3.0, 1.0, 2.0];
        let fronts = nondominated_sort_constrained(&losses, &viol);
        assert_eq!(fronts, vec![vec![1], vec![2], vec![0]]);
    }

    /// The vectorized path must replicate the scalar oracle exactly —
    /// same fronts, same nesting, same within-front order — including
    /// under NaN losses, ±0.0, infinities, and heavy ties.
    #[test]
    fn property_kernel_sort_equals_scalar_oracle() {
        check("nds_kernel_equiv", 60, |rng| {
            let n = rng.int_range(0, 80) as usize;
            let dim = rng.int_range(1, 4) as usize;
            let losses: Vec<Vec<f64>> = (0..n)
                .map(|_| {
                    (0..dim)
                        .map(|_| match rng.index(8) {
                            0 => f64::NAN,
                            1 => f64::INFINITY,
                            2 => -0.0,
                            _ => rng.int_range(0, 5) as f64,
                        })
                        .collect()
                })
                .collect();
            let fast = nondominated_sort(&losses);
            let oracle = nondominated_sort_scalar(&losses);
            prop_assert!(fast == oracle, "plain sort diverged: {fast:?} vs {oracle:?}");
            let viol: Vec<f64> = (0..n)
                .map(|_| match rng.index(3) {
                    0 => 0.0,
                    1 => f64::NAN,
                    _ => rng.uniform_range(0.0, 2.0),
                })
                .collect();
            let fast_c = nondominated_sort_constrained(&losses, &viol);
            let oracle_c = nondominated_sort_constrained_scalar(&losses, &viol);
            prop_assert!(
                fast_c == oracle_c,
                "constrained sort diverged: {fast_c:?} vs {oracle_c:?}"
            );
            Ok(())
        });
    }

    /// ISSUE 4 property: front 0 is mutually nondominated, and every
    /// excluded point is dominated by at least one front-0 member.
    #[test]
    fn property_front0_nondominated_and_covering() {
        check("nds_front0", 40, |rng| {
            let n = rng.int_range(1, 60) as usize;
            let dim = rng.int_range(2, 4) as usize;
            // coarse grid values make dominance ties/duplicates common
            let losses: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.int_range(0, 6) as f64).collect())
                .collect();
            let fronts = nondominated_sort(&losses);
            let front0 = &fronts[0];
            for (ai, &a) in front0.iter().enumerate() {
                for &b in &front0[ai + 1..] {
                    prop_assert!(
                        !dominates(&losses[a], &losses[b]) && !dominates(&losses[b], &losses[a]),
                        "front 0 members {a} and {b} not mutually nondominated"
                    );
                }
            }
            let in_front0: Vec<bool> = {
                let mut v = vec![false; n];
                front0.iter().for_each(|&i| v[i] = true);
                v
            };
            for i in (0..n).filter(|&i| !in_front0[i]) {
                prop_assert!(
                    front0.iter().any(|&f| dominates(&losses[f], &losses[i])),
                    "excluded point {i} ({:?}) dominated by nobody on the front",
                    losses[i]
                );
            }
            Ok(())
        });
    }

    /// Fronts partition the input, and ranks are consistent: nothing in
    /// front k dominates anything in front <= k.
    #[test]
    fn property_fronts_partition_and_are_ordered() {
        check("nds_partition", 40, |rng| {
            let n = rng.int_range(1, 50) as usize;
            let dim = rng.int_range(2, 4) as usize;
            let losses: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.uniform()).collect())
                .collect();
            let fronts = nondominated_sort(&losses);
            let mut seen = vec![false; n];
            for f in &fronts {
                for &i in f {
                    prop_assert!(!seen[i], "index {i} in two fronts");
                    seen[i] = true;
                }
            }
            prop_assert!(seen.iter().all(|&s| s), "some index missing from all fronts");
            for (k, f) in fronts.iter().enumerate().skip(1) {
                for &i in f {
                    // each member of front k is dominated by someone in front k-1
                    prop_assert!(
                        fronts[k - 1].iter().any(|&j| dominates(&losses[j], &losses[i])),
                        "front {k} member {i} undominated by front {}",
                        k - 1
                    );
                }
            }
            Ok(())
        });
    }
}
