//! NSGA-II (Deb et al. 2002) as a drop-in [`Sampler`], optionally with
//! Deb's constrained dominance ([`NsgaIiConfig::constraints`]).
//!
//! Ask-time flow: the relative search space is the intersection space
//! over completed trials (the same inference CMA-ES/GP use, §3.1). Once
//! `population_size` comparable trials have completed, each new trial is
//! bred jointly over that space: the elite population is selected by
//! nondominated rank + crowding distance, two parents win binary
//! tournaments, and the child is produced by simulated-binary crossover
//! (SBX) plus polynomial mutation in *internal* parameter space
//! (categoricals use uniform crossover and random-reset mutation).
//! Before the population fills — and for any parameter outside the
//! intersection space (conditional branches, first occurrences) — the
//! sampler falls back to uniform random sampling.
//!
//! Everything is seeded and behind a `Mutex`, like every other sampler
//! here, so studies are reproducible and shareable across workers.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::core::{Distribution, FrozenTrial, TrialState};
use crate::multi::nds::{
    crowding_distance, nondominated_sort, nondominated_sort_constrained, rank_crowding_cmp,
};
use crate::multi::to_losses;
use crate::sampler::{
    intersection_search_space_ctx, RandomSampler, Sampler, SearchSpace, StudyContext,
};
use crate::util::rng::Pcg64;

/// NSGA-II knobs; [`Default`] follows the literature-standard settings.
#[derive(Clone, Copy, Debug)]
pub struct NsgaIiConfig {
    /// Elite population size; also the number of completed trials required
    /// before genetic sampling starts (random warm-up until then).
    pub population_size: usize,
    /// Per-parameter probability of crossing the two parents (else the
    /// child inherits the first parent's value verbatim).
    pub crossover_prob: f64,
    /// SBX distribution index η_c (larger = children closer to parents).
    pub eta_crossover: f64,
    /// Per-parameter mutation probability; `None` = `1 / |space|`.
    pub mutation_prob: Option<f64>,
    /// Polynomial-mutation distribution index η_m.
    pub eta_mutation: f64,
    /// Feasibility-aware selection (Deb's constrained dominance over
    /// `Trial::report_constraints` values): feasible trials dominate
    /// infeasible ones, infeasible trials are ranked by total violation.
    /// Off by default — unconstrained studies are byte-identical to the
    /// pre-constraints sampler (trials without constraints are feasible
    /// with zero violation, making the two sorts agree anyway).
    pub constraints: bool,
}

impl Default for NsgaIiConfig {
    fn default() -> Self {
        NsgaIiConfig {
            population_size: 50,
            crossover_prob: 0.9,
            eta_crossover: 20.0,
            mutation_prob: None,
            eta_mutation: 20.0,
            constraints: false,
        }
    }
}

/// The multi-objective genetic sampler. See the module docs for the
/// algorithm; see [`crate::study::StudyBuilder::directions`] for wiring a
/// study to more than one objective.
pub struct NsgaIiSampler {
    cfg: NsgaIiConfig,
    rng: Mutex<Pcg64>,
}

impl NsgaIiSampler {
    pub fn new(seed: u64) -> Self {
        NsgaIiSampler::with_config(seed, NsgaIiConfig::default())
    }

    pub fn with_config(seed: u64, cfg: NsgaIiConfig) -> Self {
        assert!(cfg.population_size >= 2, "population_size must be >= 2");
        NsgaIiSampler { cfg, rng: Mutex::new(Pcg64::new(seed)) }
    }

    /// Registry constructor (spec `nsga2:population=12,constraints=true`).
    /// Knobs: `population`, `crossover`, `eta_crossover`, `mutation`,
    /// `eta_mutation`, `constraints`.
    pub fn from_config(
        cfg: &mut crate::registry::SpecConfig,
        seed: u64,
    ) -> Result<Self, String> {
        let mut c = NsgaIiConfig::default();
        if let Some(v) = cfg.get_usize("population")? {
            if v < 2 {
                return Err(format!("population must be >= 2, got {v}"));
            }
            c.population_size = v;
        }
        if let Some(v) = cfg.get_f64("crossover")? {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("crossover must be a probability in [0, 1], got {v}"));
            }
            c.crossover_prob = v;
        }
        if let Some(v) = cfg.get_f64("eta_crossover")? {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("eta_crossover must be positive, got {v}"));
            }
            c.eta_crossover = v;
        }
        if let Some(v) = cfg.get_f64("mutation")? {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("mutation must be a probability in [0, 1], got {v}"));
            }
            c.mutation_prob = Some(v);
        }
        if let Some(v) = cfg.get_f64("eta_mutation")? {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!("eta_mutation must be positive, got {v}"));
            }
            c.eta_mutation = v;
        }
        if let Some(v) = cfg.get_bool("constraints")? {
            c.constraints = v;
        }
        Ok(Self::with_config(seed, c))
    }

    /// Completed trials comparable under this study's objectives: full
    /// objective vector of the right arity and a value for every
    /// parameter of the intersection space (guaranteed for completed
    /// trials by the intersection inference itself). The third element is
    /// each member's total constraint violation (all zero when the study
    /// never reports constraints).
    fn population<'a>(
        ctx: &'a StudyContext<'_>,
        n_obj: usize,
    ) -> (Vec<&'a FrozenTrial>, Vec<Vec<f64>>, Vec<f64>) {
        let directions = ctx.directions();
        let mut pop = Vec::new();
        let mut losses = Vec::new();
        let mut violations = Vec::new();
        for t in ctx.trials.iter().filter(|t| t.state == TrialState::Complete) {
            let values = t.objective_values();
            if values.len() != n_obj {
                continue;
            }
            losses.push(to_losses(&values, directions));
            violations.push(t.total_violation());
            pop.push(t);
        }
        (pop, losses, violations)
    }
}

/// Bounded SBX: cross `x1, x2` within `[lo, hi]`, returning one child.
fn sbx(rng: &mut Pcg64, x1: f64, x2: f64, lo: f64, hi: f64, eta: f64) -> f64 {
    if (x1 - x2).abs() < 1e-14 || hi <= lo {
        return x1;
    }
    let (a, b) = if x1 < x2 { (x1, x2) } else { (x2, x1) };
    let beta = 1.0 + 2.0 * (a - lo).min(hi - b).max(0.0) / (b - a);
    let alpha = 2.0 - beta.powf(-(eta + 1.0));
    let u = rng.uniform();
    let betaq = if u <= 1.0 / alpha {
        (u * alpha).powf(1.0 / (eta + 1.0))
    } else {
        (1.0 / (2.0 - u * alpha)).powf(1.0 / (eta + 1.0))
    };
    let mid = 0.5 * (a + b);
    let spread = 0.5 * betaq * (b - a);
    let child = if rng.uniform() < 0.5 { mid - spread } else { mid + spread };
    child.clamp(lo, hi)
}

/// Bounded polynomial mutation of `x` within `[lo, hi]`.
fn polynomial_mutation(rng: &mut Pcg64, x: f64, lo: f64, hi: f64, eta: f64) -> f64 {
    let range = hi - lo;
    if range <= 0.0 {
        return x;
    }
    // a parent outside the range (enqueue_trial performs no bounds
    // validation) would drive xy below 0 and powf to NaN — clamp first
    let x = x.clamp(lo, hi);
    let u = rng.uniform();
    let mut_pow = 1.0 / (eta + 1.0);
    let deltaq = if u < 0.5 {
        let xy = 1.0 - (x - lo) / range;
        (2.0 * u + (1.0 - 2.0 * u) * xy.powf(eta + 1.0)).powf(mut_pow) - 1.0
    } else {
        let xy = 1.0 - (hi - x) / range;
        1.0 - (2.0 * (1.0 - u) + 2.0 * (u - 0.5) * xy.powf(eta + 1.0)).powf(mut_pow)
    };
    (x + deltaq * range).clamp(lo, hi)
}

impl Sampler for NsgaIiSampler {
    fn infer_relative_search_space(&self, ctx: &StudyContext<'_>) -> SearchSpace {
        intersection_search_space_ctx(ctx)
    }

    fn sample_relative(
        &self,
        ctx: &StudyContext<'_>,
        _trial_number: u64,
        space: &SearchSpace,
    ) -> BTreeMap<String, f64> {
        let n_obj = ctx.directions().len();
        let (pop, losses, violations) = Self::population(ctx, n_obj);
        if pop.len() < self.cfg.population_size || space.is_empty() {
            return BTreeMap::new(); // random warm-up via sample_independent
        }
        // elite selection: fill from successive fronts, truncating the
        // last one by descending crowding distance
        let fronts = if self.cfg.constraints {
            nondominated_sort_constrained(&losses, &violations)
        } else {
            nondominated_sort(&losses)
        };
        let mut rank = vec![0usize; pop.len()];
        let mut crowd = vec![0.0f64; pop.len()];
        let mut elite: Vec<usize> = Vec::with_capacity(self.cfg.population_size);
        for (r, front) in fronts.iter().enumerate() {
            let d = crowding_distance(&losses, front);
            for (slot, &i) in front.iter().enumerate() {
                rank[i] = r;
                crowd[i] = d[slot];
            }
            if elite.len() + front.len() <= self.cfg.population_size {
                elite.extend_from_slice(front);
            } else {
                let mut rest: Vec<usize> = front.clone();
                rest.sort_by(|&a, &b| rank_crowding_cmp(rank[a], crowd[a], rank[b], crowd[b]));
                rest.truncate(self.cfg.population_size - elite.len());
                elite.extend(rest);
            }
            if elite.len() >= self.cfg.population_size {
                break;
            }
        }

        let mut rng = self.rng.lock().unwrap();
        let mut tournament = |rng: &mut Pcg64| -> usize {
            let a = elite[rng.index(elite.len())];
            let b = elite[rng.index(elite.len())];
            match rank_crowding_cmp(rank[a], crowd[a], rank[b], crowd[b]) {
                std::cmp::Ordering::Greater => b,
                _ => a,
            }
        };
        let p1 = tournament(&mut rng);
        let p2 = tournament(&mut rng);
        let mutation_prob = self
            .cfg
            .mutation_prob
            .unwrap_or(1.0 / space.len().max(1) as f64);

        let mut child = BTreeMap::new();
        for (name, dist) in space {
            // intersection space ⇒ every completed trial carries the param
            let Some((_, x1)) = pop[p1].params.get(name) else { continue };
            let Some((_, x2)) = pop[p2].params.get(name) else { continue };
            let (x1, x2) = (*x1, *x2);
            let v = match dist {
                Distribution::Categorical { choices } => {
                    // uniform crossover, random-reset mutation
                    let mut v = if rng.uniform() < 0.5 { x1 } else { x2 };
                    if rng.uniform() < mutation_prob {
                        v = rng.index(choices.len()) as f64;
                    }
                    v
                }
                _ => {
                    let (lo, hi) = dist.internal_range();
                    let mut v = if rng.uniform() < self.cfg.crossover_prob {
                        sbx(&mut rng, x1, x2, lo, hi, self.cfg.eta_crossover)
                    } else {
                        x1
                    };
                    if rng.uniform() < mutation_prob {
                        v = polynomial_mutation(&mut rng, v, lo, hi, self.cfg.eta_mutation);
                    }
                    v
                }
            };
            child.insert(name.clone(), v);
        }
        child
    }

    fn sample_independent(
        &self,
        _ctx: &StudyContext<'_>,
        _trial_number: u64,
        _name: &str,
        dist: &Distribution,
    ) -> f64 {
        RandomSampler::draw(&mut self.rng.lock().unwrap(), dist)
    }

    fn name(&self) -> &'static str {
        "nsga2"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ParamValue, StudyDirection};

    fn multi_trial(number: u64, x: f64, y: f64, values: &[f64]) -> FrozenTrial {
        let dx = Distribution::float(0.0, 1.0);
        let dy = Distribution::float(0.0, 1.0);
        let mut t = FrozenTrial::new(number, number);
        t.params
            .insert("x".into(), (dx.clone(), dx.internal(&ParamValue::Float(x)).unwrap()));
        t.params
            .insert("y".into(), (dy.clone(), dy.internal(&ParamValue::Float(y)).unwrap()));
        t.state = TrialState::Complete;
        t.set_values(values);
        t
    }

    fn small_cfg() -> NsgaIiConfig {
        NsgaIiConfig { population_size: 4, ..NsgaIiConfig::default() }
    }

    fn dirs2() -> [StudyDirection; 2] {
        [StudyDirection::Minimize, StudyDirection::Minimize]
    }

    #[test]
    fn random_warm_up_below_population_size() {
        let s = NsgaIiSampler::with_config(0, small_cfg());
        let trials: Vec<FrozenTrial> =
            (0..3).map(|i| multi_trial(i, 0.5, 0.5, &[1.0, 1.0])).collect();
        let dirs = dirs2();
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials).with_directions(&dirs);
        let space = s.infer_relative_search_space(&ctx);
        assert_eq!(space.len(), 2);
        assert!(
            s.sample_relative(&ctx, 3, &space).is_empty(),
            "below population_size the sampler must defer to random"
        );
        // independent fallback stays inside the distribution
        let d = Distribution::float(0.0, 1.0);
        for i in 0..100 {
            let v = s.sample_independent(&ctx, i, "x", &d);
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn breeds_full_space_within_bounds_once_populated() {
        let s = NsgaIiSampler::with_config(1, small_cfg());
        let mut rng = Pcg64::new(7);
        let trials: Vec<FrozenTrial> = (0..8)
            .map(|i| {
                let x = rng.uniform();
                let y = rng.uniform();
                multi_trial(i, x, y, &[x, 1.0 - x + y])
            })
            .collect();
        let dirs = dirs2();
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials).with_directions(&dirs);
        let space = s.infer_relative_search_space(&ctx);
        for n in 0..50 {
            let child = s.sample_relative(&ctx, n, &space);
            assert_eq!(child.len(), 2, "every space param bred");
            for (name, v) in &child {
                let (lo, hi) = space[name].internal_range();
                assert!((lo..=hi).contains(v), "{name}={v} outside [{lo}, {hi}]");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let trials: Vec<FrozenTrial> = (0..6)
            .map(|i| multi_trial(i, i as f64 / 5.0, 1.0 - i as f64 / 5.0, &[i as f64, 5.0 - i as f64]))
            .collect();
        let dirs = dirs2();
        let run = |seed: u64| -> Vec<BTreeMap<String, f64>> {
            let s = NsgaIiSampler::with_config(seed, small_cfg());
            let ctx =
                StudyContext::new(StudyDirection::Minimize, &trials).with_directions(&dirs);
            let space = s.infer_relative_search_space(&ctx);
            (0..10).map(|n| s.sample_relative(&ctx, n, &space)).collect()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43), "different seeds explore differently");
    }

    #[test]
    fn mixed_arity_trials_excluded_from_population() {
        // a scalar trial (pre-multi record) must not crash or join the
        // 2-objective population
        let s = NsgaIiSampler::with_config(2, small_cfg());
        let mut trials: Vec<FrozenTrial> =
            (0..4).map(|i| multi_trial(i, 0.3, 0.7, &[1.0, 2.0])).collect();
        let mut scalar = multi_trial(4, 0.5, 0.5, &[1.0]);
        scalar.values.clear();
        scalar.value = Some(1.0);
        trials.push(scalar);
        let dirs = dirs2();
        let ctx = StudyContext::new(StudyDirection::Minimize, &trials).with_directions(&dirs);
        let space = s.infer_relative_search_space(&ctx);
        let child = s.sample_relative(&ctx, 5, &space);
        assert_eq!(child.len(), 2, "4 comparable trials = population_size, breeding starts");
    }

    #[test]
    fn constrained_selection_breeds_from_feasible_parents() {
        // Half the population sits at the (infeasible) loss optimum near
        // x=y=0.05, half at the feasible region near x=y=0.9. The
        // constraint-aware sampler's elite is all-feasible, so children
        // cluster high; the blind sampler breeds from the low cluster.
        let mut trials = Vec::new();
        let mut rng = Pcg64::new(3);
        for i in 0..8 {
            let (base, viol) = if i % 2 == 0 { (0.05, 1.0) } else { (0.9, -1.0) };
            let x = base + rng.uniform_range(0.0, 0.05);
            let y = base + rng.uniform_range(0.0, 0.05);
            let mut t = multi_trial(i, x, y, &[x, y]);
            t.constraints = vec![viol];
            trials.push(t);
        }
        let dirs = dirs2();
        let run = |constraints: bool| -> f64 {
            let s = NsgaIiSampler::with_config(
                7,
                NsgaIiConfig { population_size: 4, constraints, ..Default::default() },
            );
            let ctx =
                StudyContext::new(StudyDirection::Minimize, &trials).with_directions(&dirs);
            let space = s.infer_relative_search_space(&ctx);
            let mut sum = 0.0;
            for n in 0..40 {
                let child = s.sample_relative(&ctx, n, &space);
                sum += child["x"] + child["y"];
            }
            sum / 80.0 // mean coordinate over 40 children
        };
        let aware = run(true);
        let blind = run(false);
        assert!(aware > 0.6, "aware children should sit in the feasible cluster: {aware}");
        assert!(blind < 0.4, "blind children chase the infeasible optimum: {blind}");
    }

    #[test]
    fn sbx_and_mutation_respect_bounds() {
        let mut rng = Pcg64::new(0);
        for _ in 0..2000 {
            let c = sbx(&mut rng, 0.1, 0.9, 0.0, 1.0, 15.0);
            assert!((0.0..=1.0).contains(&c));
            let m = polynomial_mutation(&mut rng, c, 0.0, 1.0, 20.0);
            assert!((0.0..=1.0).contains(&m));
        }
        // identical parents short-circuit
        assert_eq!(sbx(&mut rng, 0.4, 0.4, 0.0, 1.0, 15.0), 0.4);
        // degenerate range is a no-op
        assert_eq!(polynomial_mutation(&mut rng, 0.5, 0.5, 0.5, 20.0), 0.5);
        // out-of-range parents (possible via unvalidated enqueue_trial)
        // are clamped, never NaN
        for _ in 0..200 {
            let m = polynomial_mutation(&mut rng, 1.7, 0.0, 1.0, 20.0);
            assert!((0.0..=1.0).contains(&m), "got {m}");
        }
    }
}
