//! The define-by-run suggest API (§2) — `Trial` and `FixedTrial`.
//!
//! An objective function receives a *living trial object* and constructs
//! the search space dynamically by calling `suggest_*` methods; each call
//! samples from the history of previously evaluated trials. Plain Rust
//! control flow (loops, conditionals, helper functions) over these calls
//! is the whole API — there is no up-front space declaration, which is
//! the paper's core design criterion (compare Fig 1 vs Fig 2).
//!
//! [`FixedTrial`] (§2.2) replays a fixed parameter set through the same
//! objective for deployment: code the objective once against
//! [`TrialApi`], tune with `Trial`, deploy with `FixedTrial`.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::core::{Distribution, FrozenTrial, IndexSnapshot, OptunaError, ParamValue};
use crate::pruner::PruningContext;
use crate::sampler::{SearchSpace, StudyContext};
use crate::study::Study;

/// The polymorphic suggest interface shared by live and fixed trials.
pub trait TrialApi {
    /// Uniform continuous parameter on [low, high].
    fn suggest_float(&mut self, name: &str, low: f64, high: f64) -> Result<f64, OptunaError> {
        self.suggest(name, Distribution::Float { low, high, log: false, step: None })
            .map(|v| v.as_f64().unwrap())
    }

    /// Log-uniform continuous parameter on [low, high] (low > 0).
    fn suggest_float_log(&mut self, name: &str, low: f64, high: f64) -> Result<f64, OptunaError> {
        self.suggest(name, Distribution::Float { low, high, log: true, step: None })
            .map(|v| v.as_f64().unwrap())
    }

    /// Discretized continuous parameter: low, low+step, …, ≤ high.
    fn suggest_float_step(
        &mut self,
        name: &str,
        low: f64,
        high: f64,
        step: f64,
    ) -> Result<f64, OptunaError> {
        self.suggest(name, Distribution::Float { low, high, log: false, step: Some(step) })
            .map(|v| v.as_f64().unwrap())
    }

    /// Uniform integer on [low, high] inclusive.
    fn suggest_int(&mut self, name: &str, low: i64, high: i64) -> Result<i64, OptunaError> {
        self.suggest(name, Distribution::Int { low, high, log: false, step: 1 })
            .map(|v| v.as_i64().unwrap())
    }

    /// Log-uniform integer on [low, high] (low ≥ 1).
    fn suggest_int_log(&mut self, name: &str, low: i64, high: i64) -> Result<i64, OptunaError> {
        self.suggest(name, Distribution::Int { low, high, log: true, step: 1 })
            .map(|v| v.as_i64().unwrap())
    }

    /// Categorical choice; returns the selected element of `choices`.
    fn suggest_categorical(
        &mut self,
        name: &str,
        choices: &[&str],
    ) -> Result<String, OptunaError> {
        self.suggest(
            name,
            Distribution::Categorical {
                choices: choices.iter().map(|c| c.to_string()).collect(),
            },
        )
        .map(|v| v.as_str().unwrap().to_string())
    }

    /// Core suggestion entry point.
    fn suggest(&mut self, name: &str, dist: Distribution) -> Result<ParamValue, OptunaError>;

    /// Report an intermediate objective value at `step` (pruning input).
    fn report(&mut self, step: u64, value: f64) -> Result<(), OptunaError>;

    /// Ask the pruner whether to stop now (Fig 5). Callers typically do
    /// `if trial.should_prune()? { return Err(OptunaError::TrialPruned); }`.
    fn should_prune(&mut self) -> Result<bool, OptunaError>;

    /// Attach a user attribute to the trial.
    fn set_user_attr(&mut self, key: &str, value: &str) -> Result<(), OptunaError>;

    /// Report the trial's constraint values: `c <= 0` means satisfied,
    /// anything positive (or NaN) violates. Feasibility-aware samplers
    /// ([`crate::multi::dominates_constrained`], constrained NSGA-II /
    /// TPE) read these off the [`FrozenTrial`]; an empty vector — or
    /// never calling this — leaves the trial unconstrained (feasible).
    fn report_constraints(&mut self, constraints: &[f64]) -> Result<(), OptunaError>;

    /// Trial number within the study.
    fn number(&self) -> u64;
}

/// A live trial bound to a study (storage + sampler + pruner).
pub struct Trial<'s> {
    pub(crate) study: &'s Study,
    pub(crate) trial_id: u64,
    pub(crate) number: u64,
    /// Joint samples proposed by the relational sampler before the
    /// objective ran (name → internal value).
    pub(crate) relative_params: BTreeMap<String, f64>,
    /// The space those samples were drawn for (guards against the
    /// objective requesting a different distribution under the same name).
    pub(crate) relative_space: SearchSpace,
    /// Parameters suggested so far in this trial (idempotent re-suggest).
    cache: BTreeMap<String, (Distribution, f64)>,
    /// Last reported (step, value) — pruned trials record this as value.
    pub(crate) last_report: Option<(u64, f64)>,
    /// History snapshot taken at ask() time, shared by every independent
    /// suggest in this trial — and, through [`crate::storage::CachedStorage`],
    /// with every concurrent worker on the same generation. One snapshot
    /// per trial instead of one per parameter, and zero clones when the
    /// study hasn't changed between asks.
    pub(crate) snapshot: Arc<Vec<FrozenTrial>>,
    /// Observation-index snapshot synced to the same generation as
    /// `snapshot` (`None` when the study runs without an index); gives
    /// samplers pre-sorted observation columns per suggest.
    pub(crate) index: Option<Arc<IndexSnapshot>>,
}

impl<'s> Trial<'s> {
    pub(crate) fn new(
        study: &'s Study,
        trial_id: u64,
        number: u64,
        relative_params: BTreeMap<String, f64>,
        relative_space: SearchSpace,
        snapshot: Arc<Vec<FrozenTrial>>,
        index: Option<Arc<IndexSnapshot>>,
    ) -> Self {
        Trial {
            study,
            trial_id,
            number,
            relative_params,
            relative_space,
            cache: BTreeMap::new(),
            last_report: None,
            snapshot,
            index,
        }
    }

    /// View of a popped `Waiting` trial (a retried configuration): the
    /// stored parameters seed the suggest cache, so `suggest_*` calls
    /// replay the enqueued values instead of sampling — and, as always,
    /// asking for a *different* distribution under the same name errors.
    pub(crate) fn resumed(
        study: &'s Study,
        trial_id: u64,
        number: u64,
        seeded: BTreeMap<String, (Distribution, f64)>,
        snapshot: Arc<Vec<FrozenTrial>>,
        index: Option<Arc<IndexSnapshot>>,
    ) -> Self {
        Trial {
            study,
            trial_id,
            number,
            relative_params: BTreeMap::new(),
            relative_space: Default::default(),
            cache: seeded,
            last_report: None,
            snapshot,
            index,
        }
    }

    pub fn id(&self) -> u64 {
        self.trial_id
    }
}

impl TrialApi for Trial<'_> {
    fn suggest(&mut self, name: &str, dist: Distribution) -> Result<ParamValue, OptunaError> {
        // Idempotent within the trial: same name ⇒ same value, and the
        // distribution must not change mid-trial.
        if let Some((cached_dist, internal)) = self.cache.get(name) {
            if *cached_dist != dist {
                return Err(OptunaError::InvalidParam(format!(
                    "parameter '{name}' re-suggested with a different distribution"
                )));
            }
            return Ok(dist.external(*internal));
        }
        let internal = if let (Some(v), Some(rel_dist)) = (
            self.relative_params.get(name),
            self.relative_space.get(name),
        ) {
            if *rel_dist == dist {
                *v
            } else {
                self.sample_independent(name, &dist)?
            }
        } else {
            self.sample_independent(name, &dist)?
        };
        self.study
            .storage
            .set_trial_param(self.trial_id, name, &dist, internal)?;
        self.cache.insert(name.to_string(), (dist.clone(), internal));
        Ok(dist.external(internal))
    }

    fn report(&mut self, step: u64, value: f64) -> Result<(), OptunaError> {
        self.last_report = Some((step, value));
        self.study
            .storage
            .set_trial_intermediate(self.trial_id, step, value)
    }

    fn should_prune(&mut self) -> Result<bool, OptunaError> {
        let Some((step, _)) = self.last_report else {
            return Ok(false); // nothing reported yet
        };
        // Fresh shared snapshot (delta-refreshed, not a full clone): the
        // pruner must see the intermediates other workers just reported,
        // and our own `report` above. The index is synced after the
        // snapshot for the same reason — its step columns must contain
        // our own report (the sync-after-report invariant pruners rely
        // on for their O(log n) queries).
        let trials = self.study.storage.get_trials_snapshot(self.study.study_id)?;
        let index = self.study.sync_obs_index()?;
        let Some(me) = trials.iter().find(|t| t.id == self.trial_id) else {
            return Err(OptunaError::Storage(
                format!("trial {} missing from snapshot", self.trial_id).into(),
            ));
        };
        let ctx = PruningContext {
            direction: self.study.direction,
            trials: &trials,
            trial: me,
            step,
            index: index.as_deref(),
        };
        Ok(self.study.pruner.should_prune(&ctx))
    }

    fn set_user_attr(&mut self, key: &str, value: &str) -> Result<(), OptunaError> {
        self.study.storage.set_trial_user_attr(self.trial_id, key, value)
    }

    fn report_constraints(&mut self, constraints: &[f64]) -> Result<(), OptunaError> {
        self.study.storage.set_trial_constraints(self.trial_id, constraints)
    }

    fn number(&self) -> u64 {
        self.number
    }
}

impl Trial<'_> {
    fn sample_independent(&self, name: &str, dist: &Distribution) -> Result<f64, OptunaError> {
        if dist.is_single() {
            let (lo, _) = dist.internal_range();
            return Ok(lo);
        }
        let ctx = StudyContext::with_index(
            self.study.direction,
            &self.snapshot,
            self.index.as_deref(),
        )
        .with_directions(&self.study.directions);
        let _span = self.study.span("sampler.suggest");
        Ok(self
            .study
            .sampler
            .sample_independent(&ctx, self.number, name, dist))
    }
}

/// Deployment trial (§2.2): replays a fixed parameter set.
pub struct FixedTrial {
    params: BTreeMap<String, ParamValue>,
    /// Params the objective asked for that were not provided.
    missing: Vec<String>,
    user_attrs: BTreeMap<String, String>,
    constraints: Vec<f64>,
}

impl FixedTrial {
    pub fn new(params: Vec<(&str, ParamValue)>) -> Self {
        FixedTrial {
            params: params
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
            missing: Vec::new(),
            user_attrs: BTreeMap::new(),
            constraints: Vec::new(),
        }
    }

    /// Build from a completed trial's recorded parameters.
    pub fn from_frozen(trial: &crate::core::FrozenTrial) -> Self {
        FixedTrial {
            params: trial
                .params
                .iter()
                .map(|(name, (dist, internal))| (name.clone(), dist.external(*internal)))
                .collect(),
            missing: Vec::new(),
            user_attrs: BTreeMap::new(),
            constraints: Vec::new(),
        }
    }

    /// Names the objective requested but the fixed set lacked.
    pub fn missing_params(&self) -> &[String] {
        &self.missing
    }

    /// Constraint values the objective reported during replay.
    pub fn reported_constraints(&self) -> &[f64] {
        &self.constraints
    }
}

impl TrialApi for FixedTrial {
    fn suggest(&mut self, name: &str, dist: Distribution) -> Result<ParamValue, OptunaError> {
        match self.params.get(name) {
            Some(v) => {
                if !dist.contains(v) {
                    return Err(OptunaError::InvalidParam(format!(
                        "fixed value {v} for '{name}' outside distribution {dist:?}"
                    )));
                }
                Ok(v.clone())
            }
            None => {
                self.missing.push(name.to_string());
                Err(OptunaError::InvalidParam(format!(
                    "FixedTrial has no value for parameter '{name}'"
                )))
            }
        }
    }

    fn report(&mut self, _step: u64, _value: f64) -> Result<(), OptunaError> {
        Ok(()) // deployment: reports are ignored
    }

    fn should_prune(&mut self) -> Result<bool, OptunaError> {
        Ok(false) // deployment: never prune
    }

    fn set_user_attr(&mut self, key: &str, value: &str) -> Result<(), OptunaError> {
        self.user_attrs.insert(key.to_string(), value.to_string());
        Ok(())
    }

    fn report_constraints(&mut self, constraints: &[f64]) -> Result<(), OptunaError> {
        self.constraints = constraints.to_vec();
        Ok(()) // deployment: recorded but drives nothing
    }

    fn number(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Live-trial behaviour is covered by study.rs tests (needs a Study);
    // here we exercise FixedTrial.

    fn objective<T: TrialApi>(t: &mut T) -> Result<f64, OptunaError> {
        let x = t.suggest_float("x", -5.0, 5.0)?;
        let n = t.suggest_int("n", 1, 4)?;
        let act = t.suggest_categorical("act", &["relu", "tanh"])?;
        let bonus = if act == "relu" { 0.0 } else { 1.0 };
        Ok(x * x + n as f64 + bonus)
    }

    #[test]
    fn fixed_trial_replays_params() {
        let mut ft = FixedTrial::new(vec![
            ("x", ParamValue::Float(2.0)),
            ("n", ParamValue::Int(3)),
            ("act", ParamValue::Cat("tanh".into())),
        ]);
        let v = objective(&mut ft).unwrap();
        assert_eq!(v, 4.0 + 3.0 + 1.0);
    }

    #[test]
    fn fixed_trial_missing_param_errors() {
        let mut ft = FixedTrial::new(vec![("x", ParamValue::Float(0.0))]);
        assert!(objective(&mut ft).is_err());
        assert_eq!(ft.missing_params(), &["n".to_string()]);
    }

    #[test]
    fn fixed_trial_out_of_domain_rejected() {
        let mut ft = FixedTrial::new(vec![
            ("x", ParamValue::Float(99.0)),
            ("n", ParamValue::Int(1)),
            ("act", ParamValue::Cat("relu".into())),
        ]);
        assert!(objective(&mut ft).is_err());
    }

    #[test]
    fn fixed_trial_report_prune_noops() {
        let mut ft = FixedTrial::new(vec![]);
        ft.report(1, 0.5).unwrap();
        assert!(!ft.should_prune().unwrap());
        ft.set_user_attr("k", "v").unwrap();
    }
}
