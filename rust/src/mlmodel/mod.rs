//! Rust driver for the L2 JAX model (the §5.2 "simplified AlexNet"
//! analog): holds the parameter/momentum literals across steps and invokes
//! the AOT-compiled `init_params` / `train_step` / `eval_step` programs
//! through PJRT.
//!
//! The model's 8 hyperparameters (matching the paper's count) are:
//! `lr`, `momentum`, `weight_decay`, `dropout` — runtime scalars — and
//! `c1`, `c2`, `c3`, `fc_units` — architecture widths realized as channel
//! masks over the maximal network, so one fixed HLO serves every trial
//! (DESIGN.md §3).

use std::sync::Arc;

use crate::core::OptunaError;
use crate::runtime::Runtime;
#[cfg(feature = "pjrt")]
use crate::runtime::{literal_f32, literal_i32, scalar_i32, to_vec_f32};
use crate::util::rng::Pcg64;

/// The tunable hyperparameters of one trial.
#[derive(Debug, Clone)]
pub struct HyperParams {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub dropout: f64,
    /// Effective widths (≤ the maximal widths in the manifest).
    pub c1: usize,
    pub c2: usize,
    pub c3: usize,
    pub fc_units: usize,
}

impl HyperParams {
    /// A reasonable mid-range default (useful for smoke tests).
    pub fn default_config() -> HyperParams {
        HyperParams {
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            dropout: 0.1,
            c1: 16,
            c2: 32,
            c3: 32,
            fc_units: 256,
        }
    }
}

/// A synthetic SVHN-like dataset: per-class templates + Gaussian noise.
/// Learnable but non-trivial; the same construction as the python-side
/// test generator.
pub struct SyntheticSvhn {
    img: usize,
    n_classes: usize,
    templates: Vec<Vec<f32>>, // per class, img*img*3
    rng: Pcg64,
}

impl SyntheticSvhn {
    pub fn new(img: usize, n_classes: usize, seed: u64) -> SyntheticSvhn {
        let mut trng = Pcg64::new(1234);
        let templates = (0..n_classes)
            .map(|_| {
                (0..img * img * 3)
                    .map(|_| trng.uniform() as f32)
                    .collect::<Vec<f32>>()
            })
            .collect();
        SyntheticSvhn { img, n_classes, templates, rng: Pcg64::new(seed) }
    }

    /// Sample a batch: (x flat [n, img, img, 3], y [n]).
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let pix = self.img * self.img * 3;
        let mut xs = Vec::with_capacity(n * pix);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = self.rng.index(self.n_classes);
            ys.push(cls as i32);
            let tpl = &self.templates[cls];
            for p in 0..pix {
                let v = tpl[p] as f64 + 0.25 * self.rng.normal();
                xs.push(v.clamp(0.0, 1.0) as f32);
            }
        }
        (xs, ys)
    }
}

/// One training session = one trial's model state.
#[cfg(feature = "pjrt")]
pub struct TrainSession {
    runtime: Arc<Runtime>,
    /// params then momentum literals, in manifest order (2·n_params).
    state: Vec<xla::Literal>,
    masks: [Vec<f32>; 4],
    hp_vec: [f32; 4],
    step_count: u64,
}

#[cfg(feature = "pjrt")]
impl TrainSession {
    /// Initialize model parameters on-device for the given hyperparams.
    pub fn new(runtime: Arc<Runtime>, hp: &HyperParams, seed: i32) -> Result<Self, OptunaError> {
        let meta = &runtime.manifest.model;
        let mask_dims: Vec<usize> = meta.mask_specs.iter().map(|(_, s)| s[0]).collect();
        let widths = [hp.c1, hp.c2, hp.c3, hp.fc_units];
        let mut masks: [Vec<f32>; 4] = Default::default();
        for i in 0..4 {
            if widths[i] > mask_dims[i] {
                return Err(OptunaError::InvalidParam(format!(
                    "width {} exceeds maximal {}",
                    widths[i], mask_dims[i]
                )));
            }
            let mut m = vec![0.0f32; mask_dims[i]];
            for v in m.iter_mut().take(widths[i]) {
                *v = 1.0;
            }
            masks[i] = m;
        }
        let state = runtime.execute("init_params", &[scalar_i32(seed)])?;
        Ok(TrainSession {
            runtime,
            state,
            masks,
            hp_vec: [
                hp.lr as f32,
                hp.momentum as f32,
                hp.weight_decay as f32,
                hp.dropout as f32,
            ],
            step_count: 0,
        })
    }

    /// One SGD step on a batch; returns the training loss.
    pub fn train_step(&mut self, x: &[f32], y: &[i32]) -> Result<f64, OptunaError> {
        let meta = &self.runtime.manifest.model;
        let b = meta.train_batch;
        let img = meta.img;
        let n_params = meta.param_specs.len();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * n_params + 8);
        // params + momentum (moved out; replaced by the step outputs)
        inputs.append(&mut self.state);
        inputs.push(literal_f32(x, &[b, img, img, 3])?);
        inputs.push(literal_i32(y, &[b])?);
        inputs.push(literal_f32(&self.hp_vec, &[4])?);
        for m in &self.masks {
            inputs.push(literal_f32(m, &[m.len()])?);
        }
        inputs.push(scalar_i32(self.step_count as i32));
        let mut outs = self.runtime.execute("train_step", &inputs)?;
        let loss_lit = outs.pop().expect("train_step outputs");
        self.state = outs; // params' + momentum'
        self.step_count += 1;
        let loss = to_vec_f32(&loss_lit)?[0] as f64;
        Ok(loss)
    }

    /// Evaluate on a batch; returns (loss, error-rate).
    pub fn eval(&self, x: &[f32], y: &[i32]) -> Result<(f64, f64), OptunaError> {
        let meta = &self.runtime.manifest.model;
        let b = meta.eval_batch;
        let img = meta.img;
        let n_params = meta.param_specs.len();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(n_params + 6);
        for lit in self.state.iter().take(n_params) {
            // Literal has no cheap clone; round-trip through raw f32s.
            let data = to_vec_f32(lit)?;
            let spec = &self.runtime.manifest.programs["eval_step"].inputs[inputs.len()];
            inputs.push(literal_f32(&data, &spec.shape)?);
        }
        inputs.push(literal_f32(x, &[b, img, img, 3])?);
        inputs.push(literal_i32(y, &[b])?);
        for m in &self.masks {
            inputs.push(literal_f32(m, &[m.len()])?);
        }
        let outs = self.runtime.execute("eval_step", &inputs)?;
        let loss = to_vec_f32(&outs[0])?[0] as f64;
        let err = to_vec_f32(&outs[1])?[0] as f64;
        Ok((loss, err))
    }

    pub fn steps_taken(&self) -> u64 {
        self.step_count
    }
}

/// Stub session compiled when the `pjrt` feature is off: construction
/// fails with `OptunaError::Runtime`, mirroring `runtime::Runtime`'s
/// stub (a `Runtime` can never be opened, so no caller reaches the
/// other methods).
#[cfg(not(feature = "pjrt"))]
pub struct TrainSession {
    step_count: u64,
}

#[cfg(not(feature = "pjrt"))]
impl TrainSession {
    pub fn new(
        _runtime: Arc<Runtime>,
        _hp: &HyperParams,
        _seed: i32,
    ) -> Result<Self, OptunaError> {
        Err(OptunaError::Runtime(
            "TrainSession needs the `pjrt` feature (vendored `xla` crate)".into(),
        ))
    }

    pub fn train_step(&mut self, _x: &[f32], _y: &[i32]) -> Result<f64, OptunaError> {
        Err(OptunaError::Runtime("pjrt feature disabled".into()))
    }

    pub fn eval(&self, _x: &[f32], _y: &[i32]) -> Result<(f64, f64), OptunaError> {
        Err(OptunaError::Runtime("pjrt feature disabled".into()))
    }

    pub fn steps_taken(&self) -> u64 {
        self.step_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    fn runtime_or_skip() -> Option<Arc<Runtime>> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Arc::new(Runtime::open(dir).unwrap()))
    }

    #[test]
    fn synthetic_data_shapes_and_classes() {
        let mut ds = SyntheticSvhn::new(16, 10, 0);
        let (x, y) = ds.batch(64);
        assert_eq!(x.len(), 64 * 16 * 16 * 3);
        assert_eq!(y.len(), 64);
        assert!(y.iter().all(|&c| (0..10).contains(&c)));
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // batches differ
        let (x2, _) = ds.batch(64);
        assert_ne!(x, x2);
    }

    #[test]
    fn templates_are_shared_across_instances() {
        let mut a = SyntheticSvhn::new(16, 10, 1);
        let mut b = SyntheticSvhn::new(16, 10, 2);
        // same class templates (deterministic), different noise
        let (xa, _) = a.batch(4);
        let (xb, _) = b.batch(4);
        assert_ne!(xa, xb);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn train_session_learns_on_synthetic_data() {
        let Some(rt) = runtime_or_skip() else { return };
        let meta = rt.manifest.model.clone();
        let hp = HyperParams::default_config();
        let mut sess = TrainSession::new(Arc::clone(&rt), &hp, 7).unwrap();
        let mut ds = SyntheticSvhn::new(meta.img, meta.n_classes, 3);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..20 {
            let (x, y) = ds.batch(meta.train_batch);
            let loss = sess.train_step(&x, &y).unwrap();
            assert!(loss.is_finite());
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap(), "loss {first:?} -> {last}");
        let (ex, ey) = ds.batch(meta.eval_batch);
        let (eloss, eerr) = sess.eval(&ex, &ey).unwrap();
        assert!(eloss.is_finite());
        assert!((0.0..=1.0).contains(&eerr));
        assert_eq!(sess.steps_taken(), 20);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn narrow_architecture_also_trains() {
        let Some(rt) = runtime_or_skip() else { return };
        let meta = rt.manifest.model.clone();
        let hp = HyperParams {
            c1: 4,
            c2: 8,
            c3: 8,
            fc_units: 32,
            ..HyperParams::default_config()
        };
        let mut sess = TrainSession::new(Arc::clone(&rt), &hp, 1).unwrap();
        let mut ds = SyntheticSvhn::new(meta.img, meta.n_classes, 5);
        let (x, y) = ds.batch(meta.train_batch);
        let loss = sess.train_step(&x, &y).unwrap();
        assert!(loss.is_finite());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn oversized_width_rejected() {
        let Some(rt) = runtime_or_skip() else { return };
        let hp = HyperParams { c1: 9999, ..HyperParams::default_config() };
        assert!(TrainSession::new(rt, &hp, 0).is_err());
    }
}
