//! `optuna` command-line interface — the Fig 7 workflow:
//!
//! ```text
//! optuna create-study --storage journal:///tmp/s.jsonl --study s1 [--direction maximize]
//! optuna optimize     --storage journal:///tmp/s.jsonl --study s1 \
//!                     --workload rocksdb --trials 50 [--sampler tpe] [--pruner asha]
//! optuna best         --storage journal:///tmp/s.jsonl --study s1
//! optuna export       --storage journal:///tmp/s.jsonl --study s1 --out trials.csv
//! optuna dashboard    --storage journal:///tmp/s.jsonl --study s1 --out report.html
//! optuna studies      --storage journal:///tmp/s.jsonl
//! ```
//!
//! Distributed optimization = run `optimize` from several processes with
//! the same `--storage` URL and `--study` name; the journal file is the
//! only coordination point (examples/distributed.rs does exactly this).

use crate::core::{OptunaError, StudyDirection};
use crate::pruner::{AshaPruner, HyperbandPruner, MedianPruner, NopPruner, Pruner};
use crate::sampler::{
    CmaEsSampler, GpSampler, RandomSampler, RfSampler, Sampler, TpeCmaEsSampler, TpeSampler,
};
use crate::storage::{InMemoryStorage, JournalStorage, Storage};
use crate::study::Study;
use crate::trial::TrialApi;
use crate::workloads::{ffmpeg_sim, hpl_sim, rocksdb_sim, svhn_surrogate};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Parsed `--key value` options + positional command.
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let command = argv.first().cloned().ok_or_else(usage)?;
        let mut opts = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --option, got '{}'", argv[i]))?;
            let val = argv
                .get(i + 1)
                .ok_or_else(|| format!("--{key} needs a value"))?;
            opts.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Args { command, opts })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn require(&self, key: &str) -> Result<&str, String> {
        self.get(key).ok_or_else(|| format!("missing --{key}"))
    }
}

fn usage() -> String {
    "usage: optuna <create-study|optimize|best|export|dashboard|studies> \
     --storage <memory:|journal://PATH> --study NAME \
     [--direction minimize|maximize] [--sampler random|tpe|cmaes|tpe+cmaes|gp|rf] \
     [--pruner none|asha|median|hyperband] [--trials N] [--seed N] \
     [--workload quadratic|rocksdb|hpl|ffmpeg|svhn-surrogate] [--out FILE]"
        .to_string()
}

/// Open a storage backend from a URL-ish string.
pub fn open_storage(url: &str) -> Result<Arc<dyn Storage>, String> {
    if url == "memory:" || url == "memory" {
        return Ok(Arc::new(InMemoryStorage::new()));
    }
    if let Some(path) = url.strip_prefix("journal://") {
        return Ok(Arc::new(JournalStorage::open(path).map_err(|e| e.to_string())?));
    }
    Err(format!("unsupported storage url '{url}' (memory: or journal://PATH)"))
}

pub fn make_sampler(kind: &str, seed: u64) -> Result<Arc<dyn Sampler>, String> {
    Ok(match kind {
        "random" => Arc::new(RandomSampler::new(seed)),
        "tpe" => Arc::new(TpeSampler::new(seed)),
        "cmaes" => Arc::new(CmaEsSampler::new(seed)),
        "tpe+cmaes" => Arc::new(TpeCmaEsSampler::new(seed)),
        "gp" => Arc::new(GpSampler::new(seed)),
        "rf" => Arc::new(RfSampler::new(seed)),
        other => return Err(format!("unknown sampler '{other}'")),
    })
}

pub fn make_pruner(kind: &str) -> Result<Arc<dyn Pruner>, String> {
    Ok(match kind {
        "none" => Arc::new(NopPruner),
        "asha" => Arc::new(AshaPruner::new()),
        "median" => Arc::new(MedianPruner::new()),
        "hyperband" => Arc::new(HyperbandPruner::new(3, 1, 4)),
        other => return Err(format!("unknown pruner '{other}'")),
    })
}

fn build_study(args: &Args, create: bool) -> Result<Study, String> {
    let storage = open_storage(args.require("storage")?)?;
    let name = args.require("study")?.to_string();
    let direction = StudyDirection::from_str(&args.get_or("direction", "minimize"))
        .map_err(|e| e.to_string())?;
    if !create && storage.get_study_id(&name).map_err(|e| e.to_string())?.is_none() {
        return Err(format!("study '{name}' does not exist in this storage"));
    }
    let seed: u64 = args.get_or("seed", "42").parse().map_err(|e| format!("bad --seed: {e}"))?;
    Study::builder()
        .name(&name)
        .direction(direction)
        .storage(storage)
        .sampler(make_sampler(&args.get_or("sampler", "tpe"), seed)?)
        .pruner(make_pruner(&args.get_or("pruner", "none"))?)
        .build()
        .map_err(|e| e.to_string())
}

/// The built-in workload objectives runnable from the CLI.
fn run_workload(study: &Study, workload: &str, n_trials: usize) -> Result<(), OptunaError> {
    match workload {
        "quadratic" => study.optimize(n_trials, |t| {
            let x = t.suggest_float("x", -10.0, 10.0)?;
            let y = t.suggest_float("y", -10.0, 10.0)?;
            Ok((x - 2.0).powi(2) + (y + 1.0).powi(2))
        }),
        "rocksdb" => study.optimize(n_trials, |t| {
            let cfg = rocksdb_sim::suggest_config(t)?;
            let chunk = cfg.chunk_seconds();
            for step in 1..=rocksdb_sim::N_CHUNKS {
                t.report(step, cfg.total_seconds())?;
                let _ = chunk;
                if t.should_prune()? {
                    return Err(OptunaError::TrialPruned);
                }
            }
            Ok(cfg.total_seconds())
        }),
        "hpl" => study.optimize(n_trials, |t| {
            let cfg = hpl_sim::suggest_config(t)?;
            Ok(cfg.gflops())
        }),
        "ffmpeg" => study.optimize(n_trials, |t| {
            let cfg = ffmpeg_sim::suggest_config(t)?;
            Ok(cfg.distortion())
        }),
        "svhn-surrogate" => study.optimize(n_trials, |t| {
            let p = svhn_surrogate::suggest_params(t)?;
            let mut curve = p.curve(t.number());
            for step in 1..=svhn_surrogate::MAX_STEPS {
                let err = curve.err_at(step);
                t.report(step, err)?;
                if t.should_prune()? {
                    return Err(OptunaError::TrialPruned);
                }
            }
            Ok(curve.final_err())
        }),
        other => Err(OptunaError::Objective(format!("unknown workload '{other}'"))),
    }
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match run_inner(argv) {
        Ok(out) => {
            print!("{out}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}\n{}", usage());
            1
        }
    }
}

fn run_inner(argv: &[String]) -> Result<String, String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "create-study" => {
            let storage = open_storage(args.require("storage")?)?;
            let name = args.require("study")?;
            let direction = StudyDirection::from_str(&args.get_or("direction", "minimize"))
                .map_err(|e| e.to_string())?;
            crate::storage::get_or_create_study(storage.as_ref(), name, direction)
                .map_err(|e| e.to_string())?;
            Ok(format!("{name}\n"))
        }
        "optimize" => {
            let study = build_study(&args, false)?;
            let n_trials: usize = args
                .get_or("trials", "20")
                .parse()
                .map_err(|e| format!("bad --trials: {e}"))?;
            let workload = args.get_or("workload", "quadratic");
            run_workload(&study, &workload, n_trials).map_err(|e| e.to_string())?;
            let best = study.best_value().map_err(|e| e.to_string())?;
            Ok(format!(
                "completed {n_trials} trials on '{workload}'; best = {}\n",
                best.map(|v| v.to_string()).unwrap_or_else(|| "n/a".into())
            ))
        }
        "best" => {
            let study = build_study(&args, false)?;
            match study.best_trial().map_err(|e| e.to_string())? {
                None => Ok("no completed trials\n".to_string()),
                Some(t) => {
                    let mut out = format!("trial #{} value {}\n", t.number, t.value.unwrap());
                    for (name, _) in t.params.iter() {
                        out.push_str(&format!("  {name} = {}\n", t.param(name).unwrap()));
                    }
                    Ok(out)
                }
            }
        }
        "export" => {
            let study = build_study(&args, false)?;
            let csv = study.to_csv().map_err(|e| e.to_string())?;
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &csv).map_err(|e| e.to_string())?;
                    Ok(format!("wrote {path}\n"))
                }
                None => Ok(csv),
            }
        }
        "dashboard" => {
            let study = build_study(&args, false)?;
            let html = crate::dashboard::render_html(&study).map_err(|e| e.to_string())?;
            let out = args.get_or("out", "report.html");
            std::fs::write(&out, &html).map_err(|e| e.to_string())?;
            Ok(format!("wrote {out}\n"))
        }
        "studies" => {
            let storage = open_storage(args.require("storage")?)?;
            let names = storage.study_names().map_err(|e| e.to_string())?;
            Ok(names.join("\n") + "\n")
        }
        other => Err(format!("unknown command '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_journal(tag: &str) -> String {
        format!(
            "journal://{}",
            std::env::temp_dir()
                .join(format!("optuna_cli_{tag}_{}.jsonl", std::process::id()))
                .display()
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn full_cli_flow() {
        let url = tmp_journal("flow");
        let out = run_inner(&argv(&[
            "create-study", "--storage", &url, "--study", "s1",
        ]))
        .unwrap();
        assert_eq!(out, "s1\n");
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "s1", "--trials", "15",
            "--sampler", "random", "--seed", "7",
        ]))
        .unwrap();
        assert!(out.contains("completed 15 trials"), "{out}");
        let out = run_inner(&argv(&["best", "--storage", &url, "--study", "s1"])).unwrap();
        assert!(out.contains("trial #"));
        assert!(out.contains("x ="));
        let out = run_inner(&argv(&["export", "--storage", &url, "--study", "s1"])).unwrap();
        assert_eq!(out.lines().count(), 16);
        let out = run_inner(&argv(&["studies", "--storage", &url])).unwrap();
        assert_eq!(out, "s1\n");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn optimize_unknown_study_errors() {
        let url = tmp_journal("missing");
        // create the journal but not the study
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "other"])).unwrap();
        let err = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "nope", "--trials", "1",
        ]))
        .unwrap_err();
        assert!(err.contains("does not exist"), "{err}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn bad_args_rejected() {
        assert!(Args::parse(&argv(&[])).is_err());
        assert!(Args::parse(&argv(&["optimize", "positional"])).is_err());
        assert!(Args::parse(&argv(&["optimize", "--trials"])).is_err());
        assert!(run_inner(&argv(&["bogus-cmd"])).is_err());
        assert!(open_storage("redis://x").is_err());
        assert!(make_sampler("genetic", 0).is_err());
        assert!(make_pruner("oracle").is_err());
    }

    #[test]
    fn workloads_run_from_cli() {
        for w in ["rocksdb", "hpl", "ffmpeg", "svhn-surrogate"] {
            let args = argv(&[
                "optimize", "--storage", "memory:", "--study", "w", "--trials", "3",
                "--workload", w, "--pruner", "asha",
                "--direction", if w == "hpl" { "maximize" } else { "minimize" },
            ]);
            // memory: storage means create-on-the-fly must work
            let err = run_inner(&args);
            assert!(err.is_err(), "memory storage without create should fail for {w}");
        }
        // with create: build_study(create=false) requires existence; use
        // journal + create-study first
        let url = tmp_journal("workloads");
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "w"])).unwrap();
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "w", "--trials", "3",
            "--workload", "rocksdb", "--pruner", "asha",
        ]))
        .unwrap();
        assert!(out.contains("best ="), "{out}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }
}
