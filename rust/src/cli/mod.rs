//! `optuna` command-line interface — the Fig 7 workflow:
//!
//! ```text
//! optuna create-study --storage journal:///tmp/s.jsonl --study s1 [--direction maximize]
//! optuna optimize     --storage journal:///tmp/s.jsonl --study s1 \
//!                     --workload rocksdb --trials 50 [--sampler tpe] [--pruner asha]
//! optuna best         --storage journal:///tmp/s.jsonl --study s1
//! optuna export       --storage journal:///tmp/s.jsonl --study s1 --out trials.csv
//! optuna dashboard    --storage journal:///tmp/s.jsonl --study s1 --out report.html
//! optuna studies      --storage journal:///tmp/s.jsonl
//! optuna compact      --storage journal:///tmp/s.jsonl [--format lines|binary]
//! ```
//!
//! `journal+bin://PATH` selects the CRC-framed binary journal (v2) when
//! creating a new file; existing files always open in whatever framing
//! is on disk. `--auto-compact-mb N` makes long-lived workers compact
//! the journal automatically once it grows past N MiB, and `compact`
//! does it once, by hand (optionally re-framing with `--format`).
//!
//! Distributed optimization = run `optimize` from several processes with
//! the same `--storage` URL and `--study` name; the journal file is the
//! only coordination point (examples/distributed.rs does exactly this).
//!
//! Two commands make that workflow fault-tolerant:
//!
//! * `worker` — a crash-safe budget-cooperating worker: heartbeats its
//!   in-flight trial, reaps stale trials abandoned by dead peers,
//!   re-enqueues their configurations, and claims shared-budget slots
//!   atomically, so N workers finish `--trials` *exactly* even if some
//!   of them are SIGKILLed mid-trial.
//! * `distributed` — an orchestrator that spawns `--workers` worker
//!   processes against one journal (optionally SIGKILLing one mid-trial
//!   with `--kill-one true`), waits, and verifies the invariants: full
//!   budget completed, zero stranded Running/Waiting trials.
//!
//! `bench-throughput` probes the storage plane itself: N threads of
//! batched ask/tell trial lifecycles against the sharded in-memory
//! backend (or, with `--baseline true`, the pre-shard single-Mutex
//! discipline) — the CLI face of `benches/fig_throughput.rs`.

use crate::core::{Distribution, OptunaError, StudyDirection, TrialState};
use crate::multi::{hypervolume, to_losses};
use crate::pruner::Pruner;
use crate::sampler::Sampler;
use crate::storage::{
    now_ms, FaultInjectionStorage, FaultSchedule, InMemoryStorage, JournalFormat,
    JournalOptions, JournalStorage, ParamSet, ResilienceConfig, ResilientStorage,
    SingleMutexStorage, Storage, TelemetryStorage, TrialFinish,
};
use crate::study::{FailoverConfig, Study, TrialOutcome};
use crate::telemetry::Telemetry;
use crate::trial::{Trial, TrialApi};
use crate::workloads::{ffmpeg_sim, hpl_sim, rocksdb_sim, svhn_surrogate};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Parsed `--key value` options + positional command.
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let command = argv.first().cloned().ok_or_else(usage)?;
        let mut opts = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --option, got '{}'", argv[i]))?;
            let val = argv
                .get(i + 1)
                .ok_or_else(|| format!("--{key} needs a value"))?;
            opts.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Args { command, opts })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn require(&self, key: &str) -> Result<&str, String> {
        self.get(key).ok_or_else(|| format!("missing --{key}"))
    }
}

fn usage() -> String {
    "usage: optuna <create-study|optimize|worker|distributed|best|pareto|export|dashboard|studies|compact|metrics|bench-throughput> \
     --storage <memory:|journal://PATH|journal+bin://PATH> --study NAME \
     [--auto-compact-mb N] [--format lines|binary] \
     [--direction minimize|maximize] [--directions minimize,maximize,..] \
     [--sampler SPEC: random|tpe|cmaes|tpe+cmaes|gp|rf|nsga2, \
      e.g. 'tpe:group=true,n_startup=20,kernel=scalar|vector'] \
     [--pruner SPEC: none|asha|median|percentile|sync-sh|hyperband, \
      e.g. 'hyperband:min_resource=1,max_resource=81,reduction=3'] [--trials N] [--seed N] \
     [--workload quadratic|rocksdb|hpl|ffmpeg|svhn-surrogate|zdt1|zdt2|dtlz2|czdt1|acclat] [--out FILE] \
     [--ref V0,V1,..] \
     [--heartbeat-ms N] [--grace-ms N] [--max-retry N] [--trial-sleep-ms N] \
     [--workers N] [--kill-one true] [--timeout-ms N] \
     [--faults 'seed=N;op=PAT,kind=K,p=P,latency-ms=N,mode=M,times=N;..'] \
     [--resilience true] [--retry N] [--retry-base-ms N] [--retry-max-ms N] \
     [--op-deadline-ms N] [--retry-jitter-seed N] \
     [--telemetry true|false] [--metrics-out FILE] [--trace-out FILE] [--json-out FILE] \
     [--threads N] [--pairs N] [--batch N] [--baseline true] [--shared-study true]"
        .to_string()
}

/// Storage-level ask/tell throughput probe: `threads` OS threads, each
/// against its **own** study (the sharded backend's best case and the
/// single-Mutex baseline's worst), each running `pairs` create+finish
/// trial lifecycles in batches of `batch` through the batched Storage
/// API. Returns elapsed seconds. Shared by the CLI `bench-throughput`
/// command and `benches/fig_throughput.rs`.
pub fn bench_ask_tell_pairs(
    storage: &dyn Storage,
    threads: usize,
    pairs: usize,
    batch: usize,
    shared_study: bool,
) -> Result<f64, String> {
    assert!(threads >= 1 && batch >= 1);
    let mut study_ids = Vec::with_capacity(threads);
    for i in 0..threads {
        let name = if shared_study { "bench-shared".to_string() } else { format!("bench-{i}") };
        let sid = crate::storage::get_or_create_study(
            storage,
            &name,
            StudyDirection::Minimize,
        )
        .map_err(|e| e.to_string())?;
        study_ids.push(sid);
    }
    let start = std::time::Instant::now();
    std::thread::scope(|scope| -> Result<(), String> {
        let mut handles = Vec::with_capacity(threads);
        for &sid in &study_ids {
            handles.push(scope.spawn(move || -> Result<(), String> {
                let mut done = 0usize;
                while done < pairs {
                    let take = batch.min(pairs - done);
                    let created =
                        storage.create_trials(sid, take).map_err(|e| e.to_string())?;
                    let finishes: Vec<TrialFinish> = created
                        .iter()
                        .map(|&(tid, n)| TrialFinish {
                            trial_id: tid,
                            state: TrialState::Complete,
                            values: vec![n as f64],
                        })
                        .collect();
                    storage.finish_trials(&finishes).map_err(|e| e.to_string())?;
                    done += take;
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| "bench thread panicked".to_string())??;
        }
        Ok(())
    })?;
    Ok(start.elapsed().as_secs_f64())
}

/// Open a storage backend from a URL-ish string.
pub fn open_storage(url: &str) -> Result<Arc<dyn Storage>, String> {
    open_storage_with(url, None)
}

/// [`open_storage`] with journal tuning: `auto_compact_mb` is the
/// `--auto-compact-mb` threshold (compact once the file exceeds N MiB).
/// `journal+bin://` selects the binary (v2) framing for newly created
/// files; an existing file always opens in whatever framing is on disk.
pub fn open_storage_with(
    url: &str,
    auto_compact_mb: Option<u64>,
) -> Result<Arc<dyn Storage>, String> {
    if url == "memory:" || url == "memory" {
        return Ok(Arc::new(InMemoryStorage::new()));
    }
    let (path, format) = if let Some(path) = url.strip_prefix("journal+bin://") {
        (path, JournalFormat::Binary)
    } else if let Some(path) = url.strip_prefix("journal://") {
        (path, JournalFormat::Lines)
    } else {
        return Err(format!(
            "unsupported storage url '{url}' (memory:, journal://PATH or journal+bin://PATH)"
        ));
    };
    let options = JournalOptions {
        format,
        auto_compact_bytes: auto_compact_mb.map(|mb| mb.saturating_mul(1024 * 1024)),
        ..Default::default()
    };
    Ok(Arc::new(JournalStorage::open_with(path, options).map_err(|e| e.to_string())?))
}

/// Parse the optional `--auto-compact-mb` flag.
fn parse_auto_compact(args: &Args) -> Result<Option<u64>, String> {
    match args.get("auto-compact-mb") {
        Some(s) => s
            .parse::<u64>()
            .map(Some)
            .map_err(|e| format!("bad --auto-compact-mb: {e}")),
        None => Ok(None),
    }
}

/// Resolve `--sampler` through the process-global algorithm registry.
/// Accepts bare names (`tpe`) and full spec strings
/// (`tpe:group=true,n_startup=20`, `nsga2:population=40,constraints=true`);
/// unknown names error with the list of registered ones.
pub fn make_sampler(spec: &str, seed: u64) -> Result<Arc<dyn Sampler>, String> {
    crate::registry::make_sampler(spec, seed)
}

/// Resolve `--pruner` through the registry; same spec grammar as
/// [`make_sampler`] (`asha:reduction=3`,
/// `hyperband:min_resource=1,max_resource=81,reduction=3`, ...).
pub fn make_pruner(spec: &str, seed: u64) -> Result<Arc<dyn Pruner>, String> {
    crate::registry::make_pruner(spec, seed)
}

/// Parse the failover flags. `default`: policy applied when the command
/// wants failover on even without explicit flags (the `worker` command);
/// `None` means failover engages only when a failover flag
/// (`--heartbeat-ms`, `--grace-ms`, `--max-retry`) is given — any one of
/// them opts in, so no flag is ever silently ignored.
fn parse_failover(
    args: &Args,
    default: Option<FailoverConfig>,
) -> Result<Option<FailoverConfig>, String> {
    let hb = args.get("heartbeat-ms");
    let any_flag =
        hb.is_some() || args.get("grace-ms").is_some() || args.get("max-retry").is_some();
    if !any_flag && default.is_none() {
        return Ok(None);
    }
    let base = default.unwrap_or_default();
    let hb_ms: u64 = match hb {
        Some(s) => s.parse().map_err(|e| format!("bad --heartbeat-ms: {e}"))?,
        None => base.heartbeat_interval.as_millis() as u64,
    };
    let grace_ms: u64 = match args.get("grace-ms") {
        Some(s) => s.parse().map_err(|e| format!("bad --grace-ms: {e}"))?,
        None => hb_ms.saturating_mul(10),
    };
    let max_retry: u32 = args
        .get_or("max-retry", "3")
        .parse()
        .map_err(|e| format!("bad --max-retry: {e}"))?;
    Ok(Some(FailoverConfig {
        heartbeat_interval: Duration::from_millis(hb_ms.max(1)),
        grace: Duration::from_millis(grace_ms.max(1)),
        max_retry,
    }))
}

/// Parse the resilience flags into a [`ResilienceConfig`]. Mirrors
/// `parse_failover`'s opt-in rule: `--resilience true` or any tuning
/// flag (`--retry`, `--retry-base-ms`, `--retry-max-ms`,
/// `--op-deadline-ms`, `--retry-jitter-seed`) turns the retry layer on,
/// so no flag is ever silently ignored; `--resilience false` forces it
/// off (the ablation switch for chaos runs).
fn parse_resilience(args: &Args) -> Result<Option<ResilienceConfig>, String> {
    match args.get("resilience") {
        Some("false" | "off" | "0") => return Ok(None),
        Some("true" | "on" | "1") | None => {}
        Some(other) => return Err(format!("bad --resilience '{other}' (true|false)")),
    }
    let any_flag = args.get("resilience").is_some()
        || args.get("retry").is_some()
        || args.get("retry-base-ms").is_some()
        || args.get("retry-max-ms").is_some()
        || args.get("op-deadline-ms").is_some()
        || args.get("retry-jitter-seed").is_some();
    if !any_flag {
        return Ok(None);
    }
    let mut cfg = ResilienceConfig::new();
    if let Some(s) = args.get("retry") {
        cfg = cfg.retries(s.parse().map_err(|e| format!("bad --retry: {e}"))?);
    }
    let base_ms: u64 = match args.get("retry-base-ms") {
        Some(s) => s.parse().map_err(|e| format!("bad --retry-base-ms: {e}"))?,
        None => cfg.base_backoff.as_millis() as u64,
    };
    let max_ms: u64 = match args.get("retry-max-ms") {
        Some(s) => s.parse().map_err(|e| format!("bad --retry-max-ms: {e}"))?,
        None => cfg.max_backoff.as_millis() as u64,
    };
    cfg = cfg.backoff(Duration::from_millis(base_ms.max(1)), Duration::from_millis(max_ms.max(1)));
    if let Some(s) = args.get("op-deadline-ms") {
        let ms: u64 = s.parse().map_err(|e| format!("bad --op-deadline-ms: {e}"))?;
        cfg = cfg.deadline(Duration::from_millis(ms.max(1)));
    }
    if let Some(s) = args.get("retry-jitter-seed") {
        cfg = cfg.jitter_seed(s.parse().map_err(|e| format!("bad --retry-jitter-seed: {e}"))?);
    }
    Ok(Some(cfg))
}

/// Parse the telemetry flags. Same opt-in rule as [`parse_resilience`]:
/// `--telemetry true` or any output flag (`--metrics-out`, `--trace-out`)
/// turns the instrumentation on, so no flag is ever silently ignored;
/// `--telemetry false` forces it off.
fn parse_telemetry(args: &Args) -> Result<bool, String> {
    match args.get("telemetry") {
        Some("false" | "off" | "0") => return Ok(false),
        Some("true" | "on" | "1") => return Ok(true),
        Some(other) => return Err(format!("bad --telemetry '{other}' (true|false)")),
        None => {}
    }
    Ok(args.get("metrics-out").is_some() || args.get("trace-out").is_some())
}

/// Seconds rendered at human scale (`12.3us`, `4.56ms`, `1.200s`).
fn fmt_secs(v: f64) -> String {
    if v < 1e-3 {
        format!("{:.1}us", v * 1e6)
    } else if v < 1.0 {
        format!("{:.2}ms", v * 1e3)
    } else {
        format!("{v:.3}s")
    }
}

/// End-of-run telemetry block appended to `optimize`/`worker` output:
/// span latencies, the resilience counters, and compaction totals.
/// Empty when the study runs without telemetry.
fn telemetry_summary(study: &Study) -> String {
    let Some(tel) = study.telemetry() else {
        return String::new();
    };
    study.fold_resilience_stats();
    let snap = tel.registry().snapshot();
    let mut out = String::new();
    let span_line = |name: &str| {
        let key = (
            "optuna_span_duration_seconds".to_string(),
            vec![("span".to_string(), name.to_string())],
        );
        let h = snap.histograms.get(&key)?;
        if h.count == 0 {
            return None;
        }
        Some(format!("{name} n={} p50={} p95={}", h.count, fmt_secs(h.p50), fmt_secs(h.p95)))
    };
    let spans: Vec<String> =
        ["study.ask", "study.ask_batch", "study.tell", "study.tell_batch", "sampler.suggest"]
            .iter()
            .filter_map(|n| span_line(n))
            .collect();
    if !spans.is_empty() {
        out.push_str(&format!("telemetry: {}\n", spans.join("; ")));
    }
    if let Some(stats) = study.resilience_stats() {
        out.push_str(&format!(
            "resilience: retries={} recovered={} exhausted={} degraded-heartbeats={} \
             degraded-compactions={} stale-reads={} absorbed-ambiguous={}\n",
            stats.retries,
            stats.recovered,
            stats.exhausted,
            stats.dropped_heartbeats,
            stats.dropped_compactions,
            stats.stale_reads,
            stats.absorbed_ambiguous
        ));
    }
    let counter = |name: &str| {
        snap.counters.get(&(name.to_string(), Vec::new())).copied().unwrap_or(0)
    };
    let compactions = counter("optuna_compactions_total");
    if compactions > 0 {
        out.push_str(&format!(
            "compaction: runs={compactions} reclaimed={}B\n",
            counter("optuna_compaction_bytes_reclaimed_total")
        ));
    }
    out
}

/// Write the `--metrics-out` / `--trace-out` files from a telemetry
/// handle: Prometheus text at the base path, a JSON snapshot beside it
/// at `<base>.json`, and the span log as JSONL. Returns "wrote ..."
/// lines for the command output.
fn write_telemetry_outputs(args: &Args, tel: &Telemetry) -> Result<String, String> {
    let mut out = String::new();
    if let Some(base) = args.get("metrics-out") {
        std::fs::write(base, tel.to_prometheus()).map_err(|e| e.to_string())?;
        let json_path = format!("{base}.json");
        std::fs::write(&json_path, tel.to_json_string()).map_err(|e| e.to_string())?;
        out.push_str(&format!("wrote {base}\nwrote {json_path}\n"));
    }
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, tel.tracer().export_jsonl()).map_err(|e| e.to_string())?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

/// Drive every [`Storage`] op once (the `metrics` command's synthetic
/// probe), so each per-op latency histogram carries at least one sample
/// and the error counters see one real failure. Study names carry `tag`
/// so re-running against a persistent journal never collides.
fn exercise_storage(s: &dyn Storage, tag: &str) -> Result<(), OptunaError> {
    let name = format!("telemetry-probe-{tag}");
    let sid = s.create_study(&name, StudyDirection::Minimize)?;
    // a deliberate duplicate create lands one Logic error in the
    // per-kind counters
    let _ = s.create_study(&name, StudyDirection::Minimize);
    let msid = s.create_study_multi(
        &format!("{name}-moo"),
        &[StudyDirection::Minimize, StudyDirection::Maximize],
    )?;
    s.get_study_id(&name)?;
    s.get_study_direction(sid)?;
    s.get_study_directions(msid)?;
    s.study_names()?;
    let (tid, _) = s.create_trial(sid)?;
    let dist = Distribution::float(0.0, 1.0);
    s.set_trial_param(tid, "x", &dist, 0.5)?;
    s.set_trial_intermediate(tid, 1, 0.9)?;
    s.set_trial_user_attr(tid, "probe", "1")?;
    s.set_trial_constraints(tid, &[-1.0])?;
    s.record_heartbeat(tid)?;
    s.finish_trial(tid, TrialState::Complete, Some(0.5))?;
    let (mid, _) = s.create_trial(msid)?;
    s.finish_trial_values(mid, TrialState::Complete, &[0.5, 1.5])?;
    let created = s.create_trials(sid, 3)?;
    let finishes: Vec<TrialFinish> = created
        .iter()
        .map(|&(trial_id, n)| TrialFinish {
            trial_id,
            state: TrialState::Complete,
            values: vec![n as f64],
        })
        .collect();
    s.finish_trials(&finishes)?;
    s.get_trial(tid)?;
    s.get_all_trials(sid)?;
    s.n_trials(sid)?;
    s.study_seq(sid)?;
    s.get_trials_since(sid, 0)?;
    s.get_trials_snapshot(sid)?;
    let mut params = ParamSet::new();
    params.insert("x".into(), (dist, 0.25));
    s.enqueue_trial(sid, &params, &BTreeMap::new())?;
    if let Some((qid, _)) = s.pop_waiting_trial(sid)? {
        s.finish_trial(qid, TrialState::Complete, Some(0.25))?;
    }
    s.fail_stale_trials(sid, Duration::from_secs(3600), &|_| None)?;
    if let Some((cid, _)) = s.create_trial_capped(sid, 1_000_000)? {
        s.finish_trial(cid, TrialState::Complete, Some(1.0))?;
    }
    s.try_compact()?;
    Ok(())
}

/// Parse an explicit `--directions a,b,..` (or scalar `--direction`) flag;
/// `Ok(None)` when neither was given.
fn parse_directions(args: &Args) -> Result<Option<Vec<StudyDirection>>, String> {
    if let Some(list) = args.get("directions") {
        let dirs = list
            .split(',')
            .map(|s| StudyDirection::from_str(s.trim()).map_err(|e| e.to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        if dirs.is_empty() {
            return Err("--directions needs at least one direction".into());
        }
        return Ok(Some(dirs));
    }
    if let Some(d) = args.get("direction") {
        return Ok(Some(vec![StudyDirection::from_str(d).map_err(|e| e.to_string())?]));
    }
    Ok(None)
}

fn build_study(
    args: &Args,
    create: bool,
    failover_default: Option<FailoverConfig>,
) -> Result<Study, String> {
    let storage = open_storage_with(args.require("storage")?, parse_auto_compact(args)?)?;
    // decorator stack, innermost first: backend ⟨ fault injection ⟨
    // resilience ⟨ snapshot cache (the builder adds the last two) —
    // injected faults exercise the retry layer, not the other way round
    let storage: Arc<dyn Storage> = match args.get("faults") {
        Some(spec) => {
            let schedule =
                FaultSchedule::parse(spec).map_err(|e| format!("bad --faults: {e}"))?;
            Arc::new(FaultInjectionStorage::new(storage, schedule))
        }
        None => storage,
    };
    // wrapped here (not via the builder) so the study lookup below is
    // already behind the retry layer when faults are being injected; the
    // concrete handle is kept so the built study can expose its counters
    let (storage, resilient): (Arc<dyn Storage>, Option<Arc<ResilientStorage>>) =
        match parse_resilience(args)? {
            Some(cfg) => {
                let r = Arc::new(ResilientStorage::new(storage, cfg));
                (r.clone(), Some(r))
            }
            None => (storage, None),
        };
    let telemetry_on = parse_telemetry(args)?;
    if telemetry_on {
        // the process-global handle, so journal-internal spans
        // (replay/compaction) land in the same registry as storage ops
        crate::telemetry::global().enable();
    }
    let name = args.require("study")?.to_string();
    let existing = storage.get_study_id(&name).map_err(|e| e.to_string())?;
    if !create && existing.is_none() {
        return Err(format!("study '{name}' does not exist in this storage"));
    }
    // explicit flags win (and must match an existing study — the builder
    // enforces that); otherwise joining a study inherits its stored
    // directions, so read-only commands (best/pareto/export/dashboard)
    // never need the flag repeated
    let directions = match parse_directions(args)? {
        Some(dirs) => dirs,
        None => match existing {
            Some(id) => storage.get_study_directions(id).map_err(|e| e.to_string())?,
            None => vec![StudyDirection::Minimize],
        },
    };
    let seed: u64 = args.get_or("seed", "42").parse().map_err(|e| format!("bad --seed: {e}"))?;
    let mut builder = Study::builder()
        .name(&name)
        .directions(&directions)
        .storage(storage)
        .sampler(make_sampler(&args.get_or("sampler", "tpe"), seed)?)
        .pruner(make_pruner(&args.get_or("pruner", "none"), seed)?);
    if let Some(cfg) = parse_failover(args, failover_default)? {
        builder = builder.failover(cfg);
    }
    if telemetry_on {
        builder = builder.telemetry(crate::telemetry::global().clone());
    }
    let mut study = builder.build().map_err(|e| e.to_string())?;
    // the retry layer was wrapped manually above, so hand the study its
    // stats handle the same way the builder's own resilience path would
    study.resilient = resilient;
    Ok(study)
}

/// A boxed CLI objective (the workload closures all erased to one type).
type Objective = Box<dyn Fn(&mut Trial<'_>) -> Result<f64, OptunaError> + Send + Sync>;

/// The built-in workload objectives runnable from the CLI.
fn workload_objective(workload: &str) -> Result<Objective, String> {
    Ok(match workload {
        "quadratic" => Box::new(|t: &mut Trial<'_>| {
            let x = t.suggest_float("x", -10.0, 10.0)?;
            let y = t.suggest_float("y", -10.0, 10.0)?;
            Ok((x - 2.0).powi(2) + (y + 1.0).powi(2))
        }),
        "rocksdb" => Box::new(|t: &mut Trial<'_>| {
            let cfg = rocksdb_sim::suggest_config(t)?;
            let chunk = cfg.chunk_seconds();
            for step in 1..=rocksdb_sim::N_CHUNKS {
                t.report(step, cfg.total_seconds())?;
                let _ = chunk;
                if t.should_prune()? {
                    return Err(OptunaError::TrialPruned);
                }
            }
            Ok(cfg.total_seconds())
        }),
        "hpl" => Box::new(|t: &mut Trial<'_>| {
            let cfg = hpl_sim::suggest_config(t)?;
            Ok(cfg.gflops())
        }),
        "ffmpeg" => Box::new(|t: &mut Trial<'_>| {
            let cfg = ffmpeg_sim::suggest_config(t)?;
            Ok(cfg.distortion())
        }),
        "svhn-surrogate" => Box::new(|t: &mut Trial<'_>| {
            let p = svhn_surrogate::suggest_params(t)?;
            let mut curve = p.curve(t.number());
            for step in 1..=svhn_surrogate::MAX_STEPS {
                let err = curve.err_at(step);
                t.report(step, err)?;
                if t.should_prune()? {
                    return Err(OptunaError::TrialPruned);
                }
            }
            Ok(curve.final_err())
        }),
        other => return Err(format!("unknown workload '{other}'")),
    })
}

fn run_workload(study: &Study, workload: &str, n_trials: usize) -> Result<(), OptunaError> {
    let obj = workload_objective(workload).map_err(OptunaError::Objective)?;
    study.optimize(n_trials, move |t| obj(t))
}

/// A boxed multi-objective CLI objective.
type MooObjective = Box<dyn Fn(&mut Trial<'_>) -> Result<Vec<f64>, OptunaError> + Send + Sync>;

/// Multi-objective workloads (the evalset MOO table plus the
/// constrained cmoo table): `None` when the workload is
/// single-objective. Returns the objective, its arity, and the
/// function's hypervolume reference point. Constrained workloads report
/// their constraint vectors from inside the objective, so the optimize
/// command's front/hypervolume reporting is feasibility-aware with no
/// extra flags.
fn moo_workload_objective(workload: &str) -> Option<(MooObjective, usize, Vec<f64>)> {
    if let Some(f) = crate::workloads::evalset::moo_functions()
        .into_iter()
        .find(|f| f.name == workload)
    {
        let (n_obj, ref_point) = (f.n_obj, f.ref_point.clone());
        let objective: MooObjective = Box::new(move |t: &mut Trial<'_>| f.objective(t));
        return Some((objective, n_obj, ref_point));
    }
    let f = crate::workloads::evalset::cmoo_functions()
        .into_iter()
        .find(|f| f.name == workload)?;
    let (n_obj, ref_point) = (f.n_obj, f.ref_point.clone());
    let objective: MooObjective = Box::new(move |t: &mut Trial<'_>| f.objective(t));
    Some((objective, n_obj, ref_point))
}

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    match run_inner(argv) {
        Ok(out) => {
            print!("{out}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}\n{}", usage());
            1
        }
    }
}

fn run_inner(argv: &[String]) -> Result<String, String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "create-study" => {
            let storage = open_storage(args.require("storage")?)?;
            let name = args.require("study")?;
            let directions = parse_directions(&args)?
                .unwrap_or_else(|| vec![StudyDirection::Minimize]);
            crate::storage::get_or_create_study_multi(storage.as_ref(), name, &directions)
                .map_err(|e| e.to_string())?;
            Ok(format!("{name}\n"))
        }
        "optimize" => {
            let n_trials: usize = args
                .get_or("trials", "20")
                .parse()
                .map_err(|e| format!("bad --trials: {e}"))?;
            let workload = args.get_or("workload", "quadratic");
            let study = build_study(&args, false, None)?;
            if let Some((objective, n_obj, ref_point)) = moo_workload_objective(&workload) {
                if study.n_objectives() != n_obj {
                    return Err(format!(
                        "workload '{workload}' has {n_obj} objectives but study \
                         '{}' has {} — create it with --directions",
                        study.name,
                        study.n_objectives()
                    ));
                }
                // the evalset MOO table defines all objectives as
                // minimized; a maximize direction would silently invert
                // an objective's front and zero the hypervolume
                if study.directions.iter().any(|d| *d != StudyDirection::Minimize) {
                    return Err(format!(
                        "workload '{workload}' minimizes every objective but study \
                         '{}' has directions [{}]",
                        study.name,
                        study
                            .directions
                            .iter()
                            .map(|d| d.as_str())
                            .collect::<Vec<_>>()
                            .join(", ")
                    ));
                }
                study
                    .optimize_multi(n_trials, move |t| objective(t))
                    .map_err(|e| e.to_string())?;
                // one front computation serves both outputs
                let front = study.best_trials().map_err(|e| e.to_string())?;
                let points: Vec<Vec<f64>> = front
                    .iter()
                    .map(|t| to_losses(&t.objective_values(), &study.directions))
                    .collect();
                let hv = hypervolume(&points, &to_losses(&ref_point, &study.directions))
                    .map_err(|e| e.to_string())?;
                let mut out = format!(
                    "completed {n_trials} trials on '{workload}'; \
                     pareto front = {} trial(s), hypervolume = {hv:.4}\n",
                    front.len()
                );
                out.push_str(&telemetry_summary(&study));
                if let Some(tel) = study.telemetry() {
                    out.push_str(&write_telemetry_outputs(&args, tel)?);
                }
                return Ok(out);
            }
            run_workload(&study, &workload, n_trials).map_err(|e| e.to_string())?;
            let best = study.best_value().map_err(|e| e.to_string())?;
            let mut out = format!(
                "completed {n_trials} trials on '{workload}'; best = {}\n",
                best.map(|v| v.to_string()).unwrap_or_else(|| "n/a".into())
            );
            out.push_str(&telemetry_summary(&study));
            if let Some(tel) = study.telemetry() {
                out.push_str(&write_telemetry_outputs(&args, tel)?);
            }
            Ok(out)
        }
        "worker" => {
            // fault-tolerant budget-cooperating worker (failover defaults
            // on; flags override). Single-objective only: the exact-budget
            // loop ranks by one value — say so instead of "unknown
            // workload" when given a MOO workload.
            if let Some(w) = args.get("workload") {
                if moo_workload_objective(w).is_some() {
                    return Err(format!(
                        "workload '{w}' is multi-objective; `worker`/`distributed` \
                         are single-objective loops — run it via `optimize`"
                    ));
                }
            }
            let study = build_study(
                &args,
                false,
                Some(FailoverConfig::new(Duration::from_millis(100))),
            )?;
            let target: u64 = args
                .get_or("trials", "20")
                .parse()
                .map_err(|e| format!("bad --trials: {e}"))?;
            let sleep_ms: u64 = args
                .get_or("trial-sleep-ms", "0")
                .parse()
                .map_err(|e| format!("bad --trial-sleep-ms: {e}"))?;
            let workload = args.get_or("workload", "quadratic");
            let inner = workload_objective(&workload)?;
            let pid = std::process::id().to_string();
            study
                .optimize_until(target, move |t| {
                    let v = inner(t)?;
                    // attributes each trial to this OS process (the
                    // orchestrator uses it to pick a mid-trial victim);
                    // set *after* the suggests so an observed trial
                    // already carries its full parameter set
                    t.set_user_attr("worker_pid", &pid)?;
                    if sleep_ms > 0 {
                        std::thread::sleep(Duration::from_millis(sleep_ms));
                    }
                    Ok(v)
                })
                .map_err(|e| e.to_string())?;
            let best = study.best_value().map_err(|e| e.to_string())?;
            let mut out = format!(
                "worker {} done; study at {target} finished trials; best = {}\n",
                std::process::id(),
                best.map(|v| v.to_string()).unwrap_or_else(|| "n/a".into())
            );
            out.push_str(&telemetry_summary(&study));
            if let Some(tel) = study.telemetry() {
                out.push_str(&write_telemetry_outputs(&args, tel)?);
            }
            Ok(out)
        }
        "distributed" => run_distributed(&args),
        "best" => {
            let study = build_study(&args, false, None)?;
            match study.best_trial().map_err(|e| e.to_string())? {
                None => Ok("no completed trials\n".to_string()),
                Some(t) => {
                    let mut out = format!("trial #{} value {}\n", t.number, t.value.unwrap());
                    for (name, _) in t.params.iter() {
                        out.push_str(&format!("  {name} = {}\n", t.param(name).unwrap()));
                    }
                    Ok(out)
                }
            }
        }
        "pareto" => {
            // print (and optionally export) the Pareto front; with --ref
            // also report the exact hypervolume
            let study = build_study(&args, false, None)?;
            let front = study.best_trials().map_err(|e| e.to_string())?;
            let mut out = format!(
                "pareto front of '{}': {} trial(s), {} objective(s)\n",
                study.name,
                front.len(),
                study.n_objectives()
            );
            for t in &front {
                let values = t
                    .objective_values()
                    .iter()
                    .map(|v| v.to_string())
                    .collect::<Vec<_>>()
                    .join(", ");
                out.push_str(&format!("trial #{} values [{values}]\n", t.number));
                for (name, _) in t.params.iter() {
                    out.push_str(&format!("  {name} = {}\n", t.param(name).unwrap()));
                }
            }
            // --ref and --out both reuse the front computed above — the
            // O(N²) nondominated sort and the storage snapshot run once
            // per invocation, not once per output
            if let Some(spec) = args.get("ref") {
                let ref_point: Vec<f64> = spec
                    .split(',')
                    .map(|s| s.trim().parse().map_err(|e| format!("bad --ref: {e}")))
                    .collect::<Result<_, _>>()?;
                if ref_point.len() != study.n_objectives() {
                    return Err(format!(
                        "--ref has {} coordinates, study has {} objectives",
                        ref_point.len(),
                        study.n_objectives()
                    ));
                }
                let reference = to_losses(&ref_point, &study.directions);
                let points: Vec<Vec<f64>> = front
                    .iter()
                    .map(|t| to_losses(&t.objective_values(), &study.directions))
                    .collect();
                let hv = hypervolume(&points, &reference).map_err(|e| e.to_string())?;
                out.push_str(&format!("hypervolume at [{spec}] = {hv}\n"));
            }
            if let Some(path) = args.get("out") {
                let csv = crate::study::trials_to_csv(&front, study.n_objectives());
                std::fs::write(path, &csv).map_err(|e| e.to_string())?;
                out.push_str(&format!("wrote {path}\n"));
            }
            Ok(out)
        }
        "export" => {
            let study = build_study(&args, false, None)?;
            let csv = study.to_csv().map_err(|e| e.to_string())?;
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &csv).map_err(|e| e.to_string())?;
                    Ok(format!("wrote {path}\n"))
                }
                None => Ok(csv),
            }
        }
        "dashboard" => {
            let study = build_study(&args, false, None)?;
            let html = crate::dashboard::render_html(&study).map_err(|e| e.to_string())?;
            let out = args.get_or("out", "report.html");
            std::fs::write(&out, &html).map_err(|e| e.to_string())?;
            Ok(format!("wrote {out}\n"))
        }
        "studies" => {
            let storage = open_storage(args.require("storage")?)?;
            let names = storage.study_names().map_err(|e| e.to_string())?;
            Ok(names.join("\n") + "\n")
        }
        "compact" => {
            // One-shot snapshot + tail compaction. `--format` re-frames
            // the journal (lines <-> binary); without it the on-disk
            // framing is kept.
            let url = args.require("storage")?;
            let path = url
                .strip_prefix("journal+bin://")
                .or_else(|| url.strip_prefix("journal://"))
                .ok_or_else(|| {
                    format!("compact requires --storage journal://PATH, got '{url}'")
                })?;
            let storage = JournalStorage::open(path).map_err(|e| e.to_string())?;
            let stats = match args.get("format") {
                None => storage.compact(),
                Some("lines") => storage.compact_as(JournalFormat::Lines),
                Some("binary") => storage.compact_as(JournalFormat::Binary),
                Some(other) => {
                    return Err(format!("unknown --format '{other}' (lines|binary)"))
                }
            }
            .map_err(|e| e.to_string())?;
            Ok(format!(
                "compacted gen {}: {} studies, {} trials, {} -> {} bytes\n",
                stats.gen, stats.studies, stats.trials, stats.bytes_before, stats.bytes_after
            ))
        }
        "metrics" => {
            // Synthetic instrumented probe: exercise the full Storage
            // surface and a short ask/tell loop behind the telemetry
            // decorator, then emit the Prometheus exposition on stdout
            // (or at --out), the JSON snapshot at --json-out, and the
            // span log at --trace-out. `--storage` targets a real
            // backend; the default is a throwaway in-memory one.
            let tel = crate::telemetry::global().clone();
            tel.enable();
            let backend: Arc<dyn Storage> = match args.get("storage") {
                Some(url) => open_storage_with(url, parse_auto_compact(&args)?)?,
                None => Arc::new(InMemoryStorage::new()),
            };
            let resilient = Arc::new(ResilientStorage::new(backend, ResilienceConfig::new()));
            let wrapped: Arc<dyn Storage> =
                Arc::new(TelemetryStorage::new(resilient.clone(), tel.clone()));
            let tag = format!("{}-{}", now_ms(), std::process::id());
            exercise_storage(wrapped.as_ref(), &tag).map_err(|e| e.to_string())?;
            // a short study run over the *unwrapped* retry layer (the
            // builder adds its own telemetry decorator) feeds the
            // ask/tell/suggest span histograms without double-counting
            // storage ops
            let seed: u64 =
                args.get_or("seed", "42").parse().map_err(|e| format!("bad --seed: {e}"))?;
            let trials: usize = args
                .get_or("trials", "20")
                .parse()
                .map_err(|e| format!("bad --trials: {e}"))?;
            let mut study = Study::builder()
                .name(&format!("telemetry-probe-study-{tag}"))
                .storage(resilient.clone() as Arc<dyn Storage>)
                .sampler(make_sampler(&args.get_or("sampler", "random"), seed)?)
                .telemetry(tel.clone())
                .build()
                .map_err(|e| e.to_string())?;
            study.resilient = Some(resilient);
            study
                .optimize(trials, |t| {
                    let x = t.suggest_float("x", -1.0, 1.0)?;
                    Ok((x - 0.3).powi(2))
                })
                .map_err(|e| e.to_string())?;
            let batch = study.ask_batch(4).map_err(|e| e.to_string())?;
            let outcomes: Vec<(Trial<'_>, TrialOutcome)> = batch
                .into_iter()
                .map(|mut t| {
                    let v = t.suggest_float("x", -1.0, 1.0).unwrap_or(0.0);
                    (t, TrialOutcome::Complete((v - 0.3).powi(2)))
                })
                .collect();
            study.tell_batch(outcomes).map_err(|e| e.to_string())?;
            let mut out = String::new();
            out.push_str(&telemetry_summary(&study));
            out.push_str(&write_telemetry_outputs(&args, &tel)?);
            if let Some(path) = args.get("json-out") {
                std::fs::write(path, tel.to_json_string()).map_err(|e| e.to_string())?;
                out.push_str(&format!("wrote {path}\n"));
            }
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, tel.to_prometheus()).map_err(|e| e.to_string())?;
                    out.push_str(&format!("wrote {path}\n"));
                    Ok(out)
                }
                // default: the exposition itself is the command output
                None => Ok(format!("{}{out}", tel.to_prometheus())),
            }
        }
        "bench-throughput" => {
            // Storage-plane throughput probe: N threads × M ask/tell
            // pairs in batches of B against a fresh in-memory backend
            // (`--baseline true` swaps in the pre-shard single-Mutex
            // discipline; `--storage` overrides the backend entirely,
            // e.g. journal://). One "pair" = one trial lifecycle
            // (create + finish).
            let threads: usize = args
                .get_or("threads", "8")
                .parse()
                .map_err(|e| format!("bad --threads: {e}"))?;
            let pairs: usize = args
                .get_or("pairs", "20000")
                .parse()
                .map_err(|e| format!("bad --pairs: {e}"))?;
            let batch: usize = args
                .get_or("batch", "1")
                .parse()
                .map_err(|e| format!("bad --batch: {e}"))?;
            if threads == 0 || batch == 0 {
                return Err("--threads and --batch must be >= 1".into());
            }
            let baseline =
                matches!(args.get_or("baseline", "false").as_str(), "true" | "1" | "yes");
            let shared =
                matches!(args.get_or("shared-study", "false").as_str(), "true" | "1" | "yes");
            let (storage, backend): (Arc<dyn Storage>, &str) = match args.get("storage") {
                Some(url) => (open_storage(url)?, "url"),
                None if baseline => (Arc::new(SingleMutexStorage::new()), "single-mutex"),
                None => (Arc::new(InMemoryStorage::new()), "sharded"),
            };
            let secs = bench_ask_tell_pairs(storage.as_ref(), threads, pairs, batch, shared)?;
            let total = (threads * pairs) as f64;
            Ok(format!(
                "bench-throughput: backend={backend} threads={threads} pairs={pairs} \
                 batch={batch} shared-study={shared}\n\
                 {:.3}s elapsed, {:.0} trials/s ({:.0} storage ops/s)\n",
                secs,
                total / secs,
                2.0 * total / secs
            ))
        }
        other => Err(format!("unknown command '{other}'")),
    }
}

/// Orchestrate `--workers` worker processes sharing one journal file,
/// optionally SIGKILLing one mid-trial (`--kill-one true`), then verify
/// the fault-tolerance invariants: the study finished its budget
/// *exactly* and no `Running`/`Waiting` trial is stranded. Returns an
/// error (non-zero exit) when any invariant is violated, so CI can gate
/// on this command directly.
fn run_distributed(args: &Args) -> Result<String, String> {
    let url = args.require("storage")?.to_string();
    if !url.starts_with("journal://") && !url.starts_with("journal+bin://") {
        return Err(
            "distributed requires --storage journal://PATH (shared across processes)".into(),
        );
    }
    let name = args.require("study")?.to_string();
    let direction = StudyDirection::from_str(&args.get_or("direction", "minimize"))
        .map_err(|e| e.to_string())?;
    let trials: u64 = args
        .get_or("trials", "24")
        .parse()
        .map_err(|e| format!("bad --trials: {e}"))?;
    let workers: usize = args
        .get_or("workers", "4")
        .parse()
        .map_err(|e| format!("bad --workers: {e}"))?;
    if workers == 0 {
        return Err("--workers must be >= 1".into());
    }
    let kill_one = matches!(args.get_or("kill-one", "false").as_str(), "true" | "1" | "yes");
    let sleep_ms: u64 = args
        .get_or("trial-sleep-ms", if kill_one { "60" } else { "0" })
        .parse()
        .map_err(|e| format!("bad --trial-sleep-ms: {e}"))?;
    let hb_ms = args.get_or("heartbeat-ms", "25");
    let grace_ms = args.get_or("grace-ms", "500");
    let max_retry = args.get_or("max-retry", "3");
    let seed: u64 = args.get_or("seed", "42").parse().map_err(|e| format!("bad --seed: {e}"))?;
    let timeout_ms: u64 = args
        .get_or("timeout-ms", "120000")
        .parse()
        .map_err(|e| format!("bad --timeout-ms: {e}"))?;
    let workload = args.get_or("workload", "quadratic");
    if moo_workload_objective(&workload).is_some() {
        return Err(format!(
            "workload '{workload}' is multi-objective; `worker`/`distributed` \
             are single-objective loops — run it via `optimize`"
        ));
    }
    let sampler = args.get_or("sampler", "tpe");
    let pruner = args.get_or("pruner", "none");

    let storage = open_storage(&url)?;
    let sid = crate::storage::get_or_create_study(storage.as_ref(), &name, direction)
        .map_err(|e| e.to_string())?;

    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let trials_s = trials.to_string();
    let sleep_s = sleep_ms.to_string();
    let mut children = Vec::new();
    for i in 0..workers {
        let seed_s = (seed + i as u64).to_string();
        let worker_args: &[&str] = &[
            "worker",
            "--storage",
            url.as_str(),
            "--study",
            name.as_str(),
            "--direction",
            direction.as_str(),
            "--trials",
            trials_s.as_str(),
            "--workload",
            workload.as_str(),
            "--sampler",
            sampler.as_str(),
            "--pruner",
            pruner.as_str(),
            "--seed",
            seed_s.as_str(),
            "--heartbeat-ms",
            hb_ms.as_str(),
            "--grace-ms",
            grace_ms.as_str(),
            "--max-retry",
            max_retry.as_str(),
            "--trial-sleep-ms",
            sleep_s.as_str(),
        ];
        // each worker writes its own metrics snapshot beside the base path
        let worker_metrics = args.get("metrics-out").map(|base| format!("{base}.w{i}"));
        let mut extra: Vec<&str> = Vec::new();
        if let Some(mb) = args.get("auto-compact-mb") {
            extra.push("--auto-compact-mb");
            extra.push(mb);
        }
        // chaos + resilience flags ride through to every worker: each
        // process injects from the same seeded schedule against the
        // shared journal, and retries/degrades behind its own wrapper
        for (flag, key) in [
            ("--faults", "faults"),
            ("--resilience", "resilience"),
            ("--retry", "retry"),
            ("--retry-base-ms", "retry-base-ms"),
            ("--retry-max-ms", "retry-max-ms"),
            ("--op-deadline-ms", "op-deadline-ms"),
            ("--retry-jitter-seed", "retry-jitter-seed"),
            ("--telemetry", "telemetry"),
        ] {
            if let Some(v) = args.get(key) {
                extra.push(flag);
                extra.push(v);
            }
        }
        if let Some(path) = &worker_metrics {
            extra.push("--metrics-out");
            extra.push(path);
        }
        let child = std::process::Command::new(&exe)
            .args(worker_args)
            .args(&extra)
            .stdout(std::process::Stdio::null())
            .spawn()
            .map_err(|e| format!("spawn worker: {e}"))?;
        children.push(child);
    }

    let deadline = std::time::Instant::now() + Duration::from_millis(timeout_ms);
    let mut killed_pid: Option<u32> = None;

    if kill_one {
        // Watch the journal for a *fresh* Running trial owned by one of
        // our children and SIGKILL that child mid-trial: the worker sets
        // `worker_pid` after its suggests and then sleeps
        // --trial-sleep-ms, so a young Running trial carrying the
        // attribute is deterministically still being "evaluated" — its
        // parameters are in storage and the kill strands it.
        let fresh_ms = (sleep_ms / 2).max(20);
        let kill_deadline = std::time::Instant::now() + Duration::from_millis(10_000);
        'hunt: while std::time::Instant::now() < kill_deadline {
            let all = storage.get_all_trials(sid).map_err(|e| e.to_string())?;
            for t in &all {
                if t.state != TrialState::Running {
                    continue;
                }
                let Some(start) = t.datetime_start else { continue };
                if now_ms().saturating_sub(start) >= fresh_ms {
                    continue;
                }
                let Some(pid_attr) = t.user_attrs.get("worker_pid") else { continue };
                if let Some(child) =
                    children.iter_mut().find(|c| c.id().to_string() == *pid_attr)
                {
                    child.kill().ok();
                    killed_pid = Some(child.id());
                    break 'hunt;
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        if killed_pid.is_none() {
            // never caught one mid-trial (tiny budgets / zero sleep):
            // fall back to killing the first worker
            children[0].kill().ok();
            killed_pid = Some(children[0].id());
        }
    }

    // wait for everyone, bounded by the global timeout
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; children.len()];
    while statuses.iter().any(|s| s.is_none()) {
        for (i, c) in children.iter_mut().enumerate() {
            if statuses[i].is_none() {
                statuses[i] = c.try_wait().map_err(|e| e.to_string())?;
            }
        }
        if std::time::Instant::now() > deadline {
            for c in children.iter_mut() {
                c.kill().ok();
                c.wait().ok();
            }
            return Err(format!("distributed run timed out after {timeout_ms}ms"));
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    for (i, (c, st)) in children.iter().zip(&statuses).enumerate() {
        if Some(c.id()) == killed_pid {
            continue; // the victim died by design
        }
        match st {
            Some(st) if st.success() => {}
            Some(st) => return Err(format!("worker {i} (pid {}) exited with {st}", c.id())),
            None => unreachable!("wait loop exits only when every status is known"),
        }
    }

    // verify the fault-tolerance invariants
    let all = storage.get_all_trials(sid).map_err(|e| e.to_string())?;
    let count = |s: TrialState| all.iter().filter(|t| t.state == s).count();
    let complete = count(TrialState::Complete);
    let pruned = count(TrialState::Pruned);
    let failed = count(TrialState::Failed);
    let running = count(TrialState::Running);
    let waiting = count(TrialState::Waiting);
    let retried = all
        .iter()
        .filter(|t| t.user_attrs.contains_key("retried_from"))
        .count();
    let out = format!(
        "distributed: {workers} workers, budget {trials}, killed {}\n\
         states: complete={complete} pruned={pruned} failed={failed} \
         running={running} waiting={waiting}\nretried={retried}\n",
        if killed_pid.is_some() { 1 } else { 0 },
    );
    if running != 0 || waiting != 0 {
        return Err(format!(
            "{out}FAIL: stranded trials (running={running}, waiting={waiting})"
        ));
    }
    if (complete + pruned) as u64 != trials {
        return Err(format!(
            "{out}FAIL: finished {} trials, budget was {trials}",
            complete + pruned
        ));
    }
    Ok(format!("{out}ok: exact budget, no stranded trials\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_journal(tag: &str) -> String {
        format!(
            "journal://{}",
            std::env::temp_dir()
                .join(format!("optuna_cli_{tag}_{}.jsonl", std::process::id()))
                .display()
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn full_cli_flow() {
        let url = tmp_journal("flow");
        let out = run_inner(&argv(&[
            "create-study", "--storage", &url, "--study", "s1",
        ]))
        .unwrap();
        assert_eq!(out, "s1\n");
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "s1", "--trials", "15",
            "--sampler", "random", "--seed", "7",
        ]))
        .unwrap();
        assert!(out.contains("completed 15 trials"), "{out}");
        let out = run_inner(&argv(&["best", "--storage", &url, "--study", "s1"])).unwrap();
        assert!(out.contains("trial #"));
        assert!(out.contains("x ="));
        let out = run_inner(&argv(&["export", "--storage", &url, "--study", "s1"])).unwrap();
        assert_eq!(out.lines().count(), 16);
        let out = run_inner(&argv(&["studies", "--storage", &url])).unwrap();
        assert_eq!(out, "s1\n");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn compact_cli_flow() {
        let url = tmp_journal("compact");
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "c1"])).unwrap();
        run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "c1", "--trials", "12",
            "--sampler", "random", "--seed", "11",
        ]))
        .unwrap();
        let out = run_inner(&argv(&["compact", "--storage", &url])).unwrap();
        assert!(out.starts_with("compacted gen 1:"), "{out}");
        assert!(out.contains("1 studies, 12 trials"), "{out}");
        // the compacted journal still serves reads and re-framing works
        let best = run_inner(&argv(&["best", "--storage", &url, "--study", "c1"])).unwrap();
        assert!(best.contains("trial #"), "{best}");
        let out = run_inner(&argv(&[
            "compact", "--storage", &url, "--format", "binary",
        ]))
        .unwrap();
        assert!(out.starts_with("compacted gen 2:"), "{out}");
        let csv = run_inner(&argv(&["export", "--storage", &url, "--study", "c1"])).unwrap();
        assert_eq!(csv.lines().count(), 13, "header + 12 trials:\n{csv}");
        // bad targets are rejected loudly
        let err =
            run_inner(&argv(&["compact", "--storage", &url, "--format", "xml"])).unwrap_err();
        assert!(err.contains("unknown --format"), "{err}");
        let err = run_inner(&argv(&["compact", "--storage", "memory:"])).unwrap_err();
        assert!(err.contains("journal://"), "{err}");
        let path = url.strip_prefix("journal://").unwrap();
        std::fs::remove_file(path).ok();
        std::fs::remove_file(format!("{path}.lock")).ok();
    }

    #[test]
    fn binary_journal_scheme_and_auto_compact_flag() {
        let lines_url = tmp_journal("binfmt");
        let path = lines_url.strip_prefix("journal://").unwrap().to_string();
        let url = format!("journal+bin://{path}");
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "b1"])).unwrap();
        // a tiny auto-compact threshold triggers during the optimize run
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "b1", "--trials", "10",
            "--sampler", "random", "--seed", "2", "--auto-compact-mb", "0",
        ]))
        .unwrap();
        assert!(out.contains("completed 10 trials"), "{out}");
        let head = std::fs::read(&path).unwrap();
        assert!(head.starts_with(b"OPTJRNL1"), "binary magic expected");
        // plain journal:// reopens the same file (on-disk framing wins)
        let best =
            run_inner(&argv(&["best", "--storage", &lines_url, "--study", "b1"])).unwrap();
        assert!(best.contains("trial #"), "{best}");
        assert!(run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "b1", "--trials", "1",
            "--auto-compact-mb", "zero",
        ]))
        .unwrap_err()
        .contains("auto-compact-mb"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(format!("{path}.lock")).ok();
    }

    #[test]
    fn optimize_unknown_study_errors() {
        let url = tmp_journal("missing");
        // create the journal but not the study
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "other"])).unwrap();
        let err = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "nope", "--trials", "1",
        ]))
        .unwrap_err();
        assert!(err.contains("does not exist"), "{err}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn bad_args_rejected() {
        assert!(Args::parse(&argv(&[])).is_err());
        assert!(Args::parse(&argv(&["optimize", "positional"])).is_err());
        assert!(Args::parse(&argv(&["optimize", "--trials"])).is_err());
        assert!(run_inner(&argv(&["bogus-cmd"])).is_err());
        assert!(open_storage("redis://x").is_err());
        // unknown algorithm names enumerate what IS registered
        let err = make_sampler("genetic", 0).unwrap_err();
        assert!(err.contains("unknown sampler 'genetic'"), "{err}");
        for name in ["random", "tpe", "cmaes", "tpe+cmaes", "gp", "rf", "nsga2"] {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        let err = make_pruner("oracle", 0).unwrap_err();
        assert!(err.contains("unknown pruner 'oracle'"), "{err}");
        for name in ["none", "asha", "median", "percentile", "sync-sh", "hyperband"] {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        // spec strings with real knobs resolve through the same path
        assert_eq!(make_sampler("tpe:group=true,n_startup=20", 0).unwrap().name(), "tpe");
        assert_eq!(make_sampler("tpe:kernel=scalar", 0).unwrap().name(), "tpe");
        let err = make_sampler("tpe:kernel=avx", 0).unwrap_err();
        assert!(err.contains("kernel"), "{err}");
        assert_eq!(
            make_pruner("hyperband:min_resource=1,max_resource=81,reduction=3", 0)
                .unwrap()
                .name(),
            "hyperband"
        );
        // malformed knobs are loud, naming the offending key
        let err = make_sampler("tpe:gamma=zero", 0).unwrap_err();
        assert!(err.contains("gamma"), "{err}");
        let err = make_pruner("asha:bogus=1", 0).unwrap_err();
        assert!(err.contains("bogus"), "{err}");
    }

    #[test]
    fn worker_command_cooperates_on_a_shared_budget() {
        let url = tmp_journal("worker");
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "w1"])).unwrap();
        let out = run_inner(&argv(&[
            "worker", "--storage", &url, "--study", "w1", "--trials", "8",
            "--sampler", "random", "--seed", "3", "--heartbeat-ms", "20",
        ]))
        .unwrap();
        assert!(out.contains("done"), "{out}");
        // budget already met: a second worker returns without adding trials
        let out2 = run_inner(&argv(&[
            "worker", "--storage", &url, "--study", "w1", "--trials", "8",
            "--sampler", "random",
        ]))
        .unwrap();
        assert!(out2.contains("done"), "{out2}");
        let csv = run_inner(&argv(&["export", "--storage", &url, "--study", "w1"])).unwrap();
        assert_eq!(csv.lines().count(), 9, "header + exactly 8 trials:\n{csv}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn multi_objective_cli_flow() {
        let url = tmp_journal("moo");
        let out = run_inner(&argv(&[
            "create-study", "--storage", &url, "--study", "m1",
            "--directions", "minimize,minimize",
        ]))
        .unwrap();
        assert_eq!(out, "m1\n");
        // optimize a 2-objective workload; directions inherited from storage
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "m1", "--trials", "6",
            "--workload", "zdt1", "--sampler", "nsga2", "--seed", "5",
        ]))
        .unwrap();
        assert!(out.contains("pareto front ="), "{out}");
        assert!(out.contains("hypervolume ="), "{out}");
        // pareto prints the front and the hypervolume at a reference
        let out = run_inner(&argv(&[
            "pareto", "--storage", &url, "--study", "m1", "--ref", "1.1,11.0",
        ]))
        .unwrap();
        assert!(out.contains("pareto front of 'm1'"), "{out}");
        assert!(out.contains("2 objective(s)"), "{out}");
        assert!(out.contains("values ["), "{out}");
        assert!(out.contains("hypervolume at [1.1,11.0]"), "{out}");
        // export carries one value column per objective
        let csv = run_inner(&argv(&["export", "--storage", &url, "--study", "m1"])).unwrap();
        assert!(csv.starts_with("number,state,value_0,value_1,"), "{csv}");
        assert_eq!(csv.lines().count(), 7, "header + 6 trials:\n{csv}");
        // `best` refuses with the typed multi-objective error
        let err = run_inner(&argv(&["best", "--storage", &url, "--study", "m1"])).unwrap_err();
        assert!(err.contains("multi-objective"), "{err}");
        // arity mismatch between workload and study is caught up front
        let err = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "m1", "--trials", "1",
            "--workload", "dtlz2",
        ]))
        .unwrap_err();
        assert!(err.contains("3 objectives"), "{err}");
        // the single-objective worker loop names the real restriction
        // instead of claiming the workload is unknown
        let err = run_inner(&argv(&[
            "worker", "--storage", &url, "--study", "m1", "--trials", "1",
            "--workload", "zdt1",
        ]))
        .unwrap_err();
        assert!(err.contains("single-objective"), "{err}");
        // wrong per-objective direction is refused, not silently inverted
        run_inner(&argv(&[
            "create-study", "--storage", &url, "--study", "m2",
            "--directions", "minimize,maximize",
        ]))
        .unwrap();
        let err = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "m2", "--trials", "1",
            "--workload", "zdt1",
        ]))
        .unwrap_err();
        assert!(err.contains("minimizes every objective"), "{err}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn constrained_workload_cli_flow() {
        let url = tmp_journal("cmoo");
        run_inner(&argv(&[
            "create-study", "--storage", &url, "--study", "c1",
            "--directions", "minimize,minimize",
        ]))
        .unwrap();
        // spec-string sampler + constrained workload through the journal
        // backend: constraints persist, so the reported front is the
        // feasibility-aware one
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "c1", "--trials", "30",
            "--workload", "czdt1", "--sampler", "nsga2:population=8,constraints=true",
            "--seed", "9",
        ]))
        .unwrap();
        assert!(out.contains("pareto front ="), "{out}");
        // every front member replayed from the journal must be feasible:
        // with 30 random-ish trials on czdt1 some feasible completion
        // exists (70% of the space is feasible), and Deb's rules then
        // exclude every infeasible point from the front
        let storage = open_storage(&url).unwrap();
        let study = crate::study::Study::builder()
            .name("c1")
            .directions(&[StudyDirection::Minimize, StudyDirection::Minimize])
            .storage(storage)
            .build()
            .unwrap();
        let front = study.best_trials().unwrap();
        assert!(!front.is_empty());
        for t in &front {
            assert!(!t.constraints.is_empty(), "constraints must persist via journal");
            assert!(t.is_feasible(), "trial {} on the front is infeasible", t.number);
        }
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn pareto_out_writes_front_csv() {
        let url = tmp_journal("pareto_out");
        run_inner(&argv(&[
            "create-study", "--storage", &url, "--study", "p1",
            "--directions", "minimize,minimize",
        ]))
        .unwrap();
        run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "p1", "--trials", "5",
            "--workload", "zdt2", "--sampler", "random", "--seed", "1",
        ]))
        .unwrap();
        let out_path = std::env::temp_dir()
            .join(format!("optuna_cli_front_{}.csv", std::process::id()));
        let out = run_inner(&argv(&[
            "pareto", "--storage", &url, "--study", "p1",
            "--out", out_path.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.contains("wrote "), "{out}");
        let csv = std::fs::read_to_string(&out_path).unwrap();
        assert!(csv.starts_with("number,state,value_0,value_1,"), "{csv}");
        assert!(csv.lines().count() >= 2, "front has at least one member:\n{csv}");
        std::fs::remove_file(out_path).ok();
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn bench_throughput_runs_small() {
        let out = run_inner(&argv(&[
            "bench-throughput", "--threads", "2", "--pairs", "50", "--batch", "8",
        ]))
        .unwrap();
        assert!(out.contains("backend=sharded"), "{out}");
        assert!(out.contains("trials/s"), "{out}");
        let out = run_inner(&argv(&[
            "bench-throughput", "--threads", "2", "--pairs", "50", "--baseline", "true",
            "--shared-study", "true",
        ]))
        .unwrap();
        assert!(out.contains("backend=single-mutex"), "{out}");
        assert!(run_inner(&argv(&["bench-throughput", "--threads", "0"])).is_err());
    }

    #[test]
    fn distributed_requires_journal_storage() {
        let err = run_inner(&argv(&[
            "distributed", "--storage", "memory:", "--study", "x",
        ]))
        .unwrap_err();
        assert!(err.contains("journal://"), "{err}");
    }

    #[test]
    fn failover_flags_parse() {
        let args = Args::parse(&argv(&[
            "worker", "--heartbeat-ms", "50", "--max-retry", "7",
        ]))
        .unwrap();
        let cfg = parse_failover(&args, None).unwrap().unwrap();
        assert_eq!(cfg.heartbeat_interval, Duration::from_millis(50));
        assert_eq!(cfg.grace, Duration::from_millis(500), "grace defaults to 10x");
        assert_eq!(cfg.max_retry, 7);
        // no flags, no default: failover stays off
        let plain = Args::parse(&argv(&["optimize"])).unwrap();
        assert!(parse_failover(&plain, None).unwrap().is_none());
        // command default engages without flags
        let cfg = parse_failover(&plain, Some(FailoverConfig::default())).unwrap().unwrap();
        assert_eq!(cfg.heartbeat_interval, Duration::from_millis(500));
        // any failover flag opts in — --grace-ms alone must not be
        // silently dropped
        let grace_only = Args::parse(&argv(&["optimize", "--grace-ms", "2000"])).unwrap();
        let cfg = parse_failover(&grace_only, None).unwrap().unwrap();
        assert_eq!(cfg.grace, Duration::from_millis(2000));
        assert_eq!(cfg.heartbeat_interval, Duration::from_millis(500), "default heartbeat");
    }

    #[test]
    fn resilience_flags_parse() {
        // no flags: the retry layer stays off
        let plain = Args::parse(&argv(&["optimize"])).unwrap();
        assert!(parse_resilience(&plain).unwrap().is_none());
        // the toggle alone yields the defaults
        let on = Args::parse(&argv(&["worker", "--resilience", "true"])).unwrap();
        let cfg = parse_resilience(&on).unwrap().unwrap();
        assert_eq!(cfg.max_retries, ResilienceConfig::new().max_retries);
        // any tuning flag opts in — --retry alone must not be dropped
        let tuned = Args::parse(&argv(&[
            "worker", "--retry", "3", "--retry-base-ms", "2", "--op-deadline-ms", "250",
        ]))
        .unwrap();
        let cfg = parse_resilience(&tuned).unwrap().unwrap();
        assert_eq!(cfg.max_retries, 3);
        assert_eq!(cfg.base_backoff, Duration::from_millis(2));
        assert_eq!(cfg.op_deadline, Duration::from_millis(250));
        // the explicit off switch wins over tuning flags (ablation runs)
        let off = Args::parse(&argv(&[
            "worker", "--resilience", "false", "--retry", "3",
        ]))
        .unwrap();
        assert!(parse_resilience(&off).unwrap().is_none());
        let bad = Args::parse(&argv(&["worker", "--resilience", "maybe"])).unwrap();
        assert!(parse_resilience(&bad).is_err());
    }

    #[test]
    fn worker_command_completes_under_injected_faults() {
        let url = tmp_journal("chaos-cli");
        // a deliberately nasty but transient schedule; the resilience
        // layer + failover loop must still land the exact budget
        let out = run_inner(&argv(&[
            "worker", "--storage", &url, "--study", "chaos", "--trials", "6",
            "--sampler", "random", "--faults", "seed=11;kind=busy,p=0.1",
            "--resilience", "true", "--retry-base-ms", "1", "--retry-max-ms", "2",
            "--heartbeat-ms", "10", "--grace-ms", "30000",
        ]))
        .unwrap();
        assert!(out.contains("done; study at 6 finished trials"), "{out}");
        // ablation: a deterministic one-shot fault on the study lookup
        // (which runs before the failover loop can ride anything out)
        // must kill the run when the retry layer is off...
        let one_shot = "seed=3;op=get_study_id,kind=timeout,p=1,times=1";
        let err = run_inner(&argv(&[
            "worker", "--storage", &url, "--study", "chaos", "--trials", "6",
            "--sampler", "random", "--faults", one_shot, "--resilience", "false",
        ]))
        .unwrap_err();
        assert!(err.contains("injected timeout fault"), "{err}");
        // ...and be absorbed by one retry when it is on
        let out = run_inner(&argv(&[
            "worker", "--storage", &url, "--study", "chaos", "--trials", "6",
            "--sampler", "random", "--faults", one_shot, "--resilience", "true",
            "--retry-base-ms", "1", "--retry-max-ms", "2",
        ]))
        .unwrap();
        assert!(out.contains("done; study at 6 finished trials"), "{out}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn metrics_command_emits_prometheus_and_json() {
        let pid = std::process::id();
        let prom = std::env::temp_dir().join(format!("optuna_cli_metrics_{pid}.prom"));
        let json = std::env::temp_dir().join(format!("optuna_cli_metrics_{pid}.json"));
        let trace = std::env::temp_dir().join(format!("optuna_cli_metrics_{pid}.jsonl"));
        let out = run_inner(&argv(&[
            "metrics", "--trials", "10",
            "--out", prom.to_str().unwrap(),
            "--json-out", json.to_str().unwrap(),
            "--trace-out", trace.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(out.contains("telemetry: study.ask"), "{out}");
        assert!(out.contains("resilience: retries="), "{out}");
        let text = std::fs::read_to_string(&prom).unwrap();
        // every Storage op's latency histogram carries samples
        for op in crate::storage::OP_NAMES {
            assert!(
                text.contains(&format!("op=\"{op}\"")),
                "no histogram for op '{op}':\n{text}"
            );
        }
        assert!(text.contains("# TYPE optuna_storage_op_duration_seconds summary"), "{text}");
        // the probe's deliberate duplicate create lands one logic error
        assert!(text.contains("optuna_storage_errors_total{kind=\"logic\"} 1"), "{text}");
        // every error kind is pre-registered even at zero
        for kind in ["io", "busy", "timeout", "poisoned", "corrupt"] {
            assert!(text.contains(&format!("kind=\"{kind}\"")), "missing {kind}:\n{text}");
        }
        // span timings for the ask/tell loop and the batched path
        for span in
            ["study.ask", "study.tell", "study.ask_batch", "study.tell_batch", "sampler.suggest"]
        {
            assert!(text.contains(&format!("span=\"{span}\"")), "missing {span}:\n{text}");
        }
        assert!(text.contains("optuna_resilience_retries"), "{text}");
        let doc = std::fs::read_to_string(&json).unwrap();
        for section in ["\"counters\"", "\"gauges\"", "\"histograms\"", "\"p95\""] {
            assert!(doc.contains(section), "missing {section}:\n{doc}");
        }
        // the span log is one JSON object per line
        let log = std::fs::read_to_string(&trace).unwrap();
        assert!(!log.is_empty());
        for line in log.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        }
        // without --out the exposition itself is the command output
        let out = run_inner(&argv(&["metrics", "--trials", "3"])).unwrap();
        assert!(out.contains("# TYPE optuna_storage_op_duration_seconds summary"), "{out}");
        for p in [&prom, &json, &trace] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn optimize_with_telemetry_writes_snapshots_and_summary() {
        let url = tmp_journal("telemetry");
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "t1"])).unwrap();
        let base = std::env::temp_dir()
            .join(format!("optuna_cli_tel_{}.prom", std::process::id()));
        let base_s = base.to_str().unwrap().to_string();
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "t1", "--trials", "10",
            "--sampler", "random", "--seed", "7", "--telemetry", "true",
            "--resilience", "true", "--metrics-out", &base_s,
        ]))
        .unwrap();
        assert!(out.contains("completed 10 trials"), "{out}");
        assert!(out.contains("telemetry: study.ask"), "{out}");
        assert!(out.contains("resilience: retries="), "{out}");
        assert!(out.contains(&format!("wrote {base_s}")), "{out}");
        let text = std::fs::read_to_string(&base).unwrap();
        assert!(text.contains("op=\"create_trial\""), "{text}");
        assert!(text.contains("span=\"study.ask\""), "{text}");
        let doc = std::fs::read_to_string(format!("{base_s}.json")).unwrap();
        assert!(doc.contains("\"histograms\""), "{doc}");
        // --metrics-out alone opts in (no --telemetry needed)...
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "t1", "--trials", "2",
            "--sampler", "random", "--metrics-out", &base_s,
        ]))
        .unwrap();
        assert!(out.contains("telemetry: study.ask"), "{out}");
        // ...and the explicit off switch wins over output flags
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "t1", "--trials", "2",
            "--sampler", "random", "--telemetry", "false", "--metrics-out", &base_s,
        ]))
        .unwrap();
        assert!(!out.contains("telemetry:"), "{out}");
        let bad = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "t1", "--trials", "1",
            "--telemetry", "maybe",
        ]))
        .unwrap_err();
        assert!(bad.contains("bad --telemetry"), "{bad}");
        std::fs::remove_file(&base).ok();
        std::fs::remove_file(format!("{base_s}.json")).ok();
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }

    #[test]
    fn workloads_run_from_cli() {
        for w in ["rocksdb", "hpl", "ffmpeg", "svhn-surrogate"] {
            let args = argv(&[
                "optimize", "--storage", "memory:", "--study", "w", "--trials", "3",
                "--workload", w, "--pruner", "asha",
                "--direction", if w == "hpl" { "maximize" } else { "minimize" },
            ]);
            // memory: storage means create-on-the-fly must work
            let err = run_inner(&args);
            assert!(err.is_err(), "memory storage without create should fail for {w}");
        }
        // with create: build_study(create=false) requires existence; use
        // journal + create-study first
        let url = tmp_journal("workloads");
        run_inner(&argv(&["create-study", "--storage", &url, "--study", "w"])).unwrap();
        let out = run_inner(&argv(&[
            "optimize", "--storage", &url, "--study", "w", "--trials", "3",
            "--workload", "rocksdb", "--pruner", "asha",
        ]))
        .unwrap();
        assert!(out.contains("best ="), "{out}");
        std::fs::remove_file(url.strip_prefix("journal://").unwrap()).ok();
    }
}
